"""Paper-scale trace replay: FASTLIBRA vs vLLM vs S-LoRA on Llama-7B.

Uses the discrete-event simulator (real cache-manager code, virtual clock)
to replay a chatbot trace and print the paper's headline metrics.

    PYTHONPATH=src python examples/trace_replay_sim.py \
        [--scenario chatbot|translation|agent] [--loras 100] [--qps 1.2]
"""

import argparse

from repro import configs
from repro.data import TraceConfig, generate_trace, trace_stats
from repro.sim import DeployedModel, ServingSimulator, SimConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="chatbot")
    ap.add_argument("--loras", type=int, default=100)
    ap.add_argument("--qps", type=float, default=1.2)
    ap.add_argument("--duration", type=float, default=240.0)
    ap.add_argument("--model", default="llama-7b")
    args = ap.parse_args()

    trace = generate_trace(TraceConfig(
        scenario=args.scenario, n_loras=args.loras,
        duration=args.duration, mean_qps=args.qps, seed=7,
    ))
    print("trace:", trace_stats(trace))
    cards = {"llama-7b": 1, "llama-13b": 2, "llama-34b": 4}[args.model]
    dep = DeployedModel(configs.get(args.model), cards=cards)
    print(f"{args.model} on {cards} NPU(s); unified pool "
          f"{dep.hbm_pool_bytes()/2**30:.1f} GiB\n")
    header = (f"{'system':12s} {'TTFT ms':>9s} {'TPOT ms':>8s} {'queue':>8s} "
              f"{'loraCS':>7s} {'kvCS':>7s} {'kv-hit':>7s} {'invalid':>8s}")
    print(header)
    for variant in ("fastlibra", "vllm", "slora", "wom", "wos", "wol"):
        res = ServingSimulator(dep, trace, SimConfig(variant=variant)).run()
        s = res.summary()
        print(f"{variant:12s} {s['avg_ttft']*1e3:9.1f} {s['avg_tpot']*1e3:8.2f} "
              f"{s['avg_queue']*1e3:8.1f} {s['avg_lora_cold']*1e3:7.1f} "
              f"{s['avg_kv_cold']*1e3:7.1f} {s['kv_hit_rate']:7.3f} "
              f"{s['avg_invalid_kv']:8.3f}")


if __name__ == "__main__":
    main()
