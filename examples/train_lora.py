"""Train LoRA adapters on a frozen base model (~100M-class reduced config)
for a few hundred steps with async checkpointing and crash-safe resume.

    PYTHONPATH=src python examples/train_lora.py [--steps 200] [--arch gemma-2b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed import CheckpointManager
from repro.models import build_model, make_train_state, make_train_step


def synthetic_batch(key, vocab: int, batch: int, seq: int, n_adapters: int):
    """Deterministic per-adapter token distributions: each adapter's 'task'
    biases the label stream so LoRA-only training has signal."""
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, vocab)
    adapter_ids = jax.random.randint(k2, (batch,), 0, n_adapters)
    labels = (tokens * 31 + adapter_ids[:, None] * 7 + 1) % vocab
    return {"tokens": tokens, "labels": labels, "adapter_ids": adapter_ids}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lora_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))
    model = build_model(cfg, dtype=jnp.float32)
    state = make_train_state(model, jax.random.PRNGKey(0), n_lora_slots=4,
                             train_lora_only=True)
    step_fn = jax.jit(make_train_step(model, lr=3e-3, train_lora_only=True))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    latest = mgr.latest_step()
    if latest is not None:
        state = mgr.restore(latest, jax.eval_shape(lambda: state))
        start = latest
        print(f"resumed from checkpoint step {latest}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_batch(jax.random.PRNGKey(step), cfg.vocab_size,
                                args.batch, args.seq, 4)
        state, metrics = step_fn(state, batch)
        if (step + 1) % 20 == 0:
            print(f"step {step+1:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(step-start+1)*1e3:.0f} ms/step)")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step + 1, state)
    mgr.wait()
    print(f"done; adapters trained LoRA-only (base frozen), "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
