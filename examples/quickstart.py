"""Quickstart: build an assigned architecture, attach two LoRA adapters, and
greedily decode with multi-LoRA batching (one engine step at a time).

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-0.6b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import build_model, make_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=configs.ARCH_IDS)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get(args.arch))  # CPU-sized same-family model
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model} "
          f"{cfg.num_params()/1e6:.1f}M params, family={cfg.family})")
    model = build_model(cfg, dtype=jnp.float32)
    state = make_train_state(model, jax.random.PRNGKey(0), n_lora_slots=2)

    # two sequences, two different adapters, one batch (SGMV semantics)
    prompts = jnp.array([[5, 7, 11, 13], [17, 19, 23, 29]], jnp.int32)
    adapter_ids = jnp.array([0, 1], jnp.int32)
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
        logits, cache = model.prefill(state.params, frames, prompts,
                                      max_len=64, lora=state.lora,
                                      adapter_ids=adapter_ids)
    else:
        logits, cache = model.prefill(state.params, prompts, max_len=64,
                                      lora=state.lora, adapter_ids=adapter_ids)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(args.tokens - 1):
        logits, cache = model.decode(state.params, cache, tok[:, None],
                                     lora=state.lora, adapter_ids=adapter_ids)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = jnp.stack(out, axis=1)
    for b in range(2):
        print(f"seq{b} (adapter {int(adapter_ids[b])}): "
              f"{list(map(int, prompts[b]))} -> {list(map(int, gen[b]))}")
    print(f"cache len: {list(map(int, cache['len']))}")


if __name__ == "__main__":
    main()
