"""End-to-end multi-LoRA serving driver (the paper's setting, real JAX).

Runs the continuous-batching ServingEngine with FASTLIBRA cache management:
multi-turn conversations across several adapters, prefix KV reuse through
the dependency tree, proactive swapping via the cost-model swapper. Prints
the per-request latencies and the serving report.

    PYTHONPATH=src python examples/multi_lora_serving.py \
        [--variant fastlibra|vllm|slora] [--requests 12]
"""

import argparse
import random

import jax

from repro import configs
from repro.serving import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="fastlibra")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--adapters", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    engine = ServingEngine(
        cfg,
        EngineConfig(
            hbm_bytes=8 << 20, host_bytes=64 << 20, block_size=4,
            max_batch_slots=4, max_seq_len=128, variant=args.variant,
        ),
        key=jax.random.PRNGKey(args.seed),
    )
    for i in range(args.adapters):
        engine.register_adapter(f"lora-{i}")

    rng = random.Random(args.seed)
    conversations: dict[int, tuple] = {}
    rid = 0
    for _ in range(args.requests):
        conv = rng.randrange(max(1, args.requests // 2))
        adapter = f"lora-{conv % args.adapters}"
        history = conversations.get(conv, ())
        new = tuple(rng.randrange(10, 200) for _ in range(rng.randint(4, 10)))
        prompt = history + new
        rid += 1
        req = Request(f"r{rid}", adapter, prompt, max_new_tokens=6)
        engine.submit(req)
        report = engine.run()
        conversations[conv] = req.full_tokens
        print(f"r{rid} conv={conv} adapter={adapter} prompt={len(prompt)}t "
              f"matched={req.matched_tokens}t ttft={req.ttft*1e3:7.1f}ms "
              f"tpot={req.tpot*1e3 if req.tpot else 0:6.2f}ms "
              f"gen={req.generated}")

    print("\n=== serving report ===")
    for k, v in report.row().items():
        print(f"{k:22s} {v:.4f}" if isinstance(v, float) else f"{k:22s} {v}")
    engine.manager.check_invariants()
    print("cache-manager invariants: OK (zero invalid KVs)")


if __name__ == "__main__":
    main()
