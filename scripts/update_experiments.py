"""Regenerate the EXPERIMENTS.md §Roofline table from results/dryrun."""

import pathlib
import re
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.roofline import load_records, markdown_table  # noqa: E402

ROOT = pathlib.Path(__file__).resolve().parents[1]


def main() -> None:
    recs = load_records()
    baselines = [r for r in recs if not r.get("opts")]
    opts = [r for r in recs if r.get("opts")]
    n_ok = sum(1 for r in baselines if r.get("status") == "ok")
    header = (
        f"\n*{len(baselines)} baseline cells compiled "
        f"({n_ok} ok) + {len(opts)} optimized §Perf variants; regenerate with "
        f"`python scripts/update_experiments.py`.*\n\n"
    )
    table = header + markdown_table(baselines) + (
        "\n\nOptimized (§Perf) variants:\n\n" + markdown_table(opts) if opts else ""
    )
    md = (ROOT / "EXPERIMENTS.md").read_text()
    begin, end = "<!-- ROOFLINE-TABLE -->", "<!-- /ROOFLINE-TABLE -->"
    i, j = md.index(begin) + len(begin), md.index(end)
    md = md[:i] + "\n" + table + "\n" + md[j:]
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print(f"updated EXPERIMENTS.md with {len(recs)} cells")


if __name__ == "__main__":
    main()
