"""Paged KV cache + SSM state-snapshot substrate."""

from .paged import KVPoolSpec, PagedKVPool
from .state_cache import (
    StateCache,
    StateSpec,
    flat_state_elems,
    flatten_state,
    state_floats,
    unflatten_state,
)

__all__ = [
    "KVPoolSpec",
    "PagedKVPool",
    "StateCache",
    "StateSpec",
    "flat_state_elems",
    "flatten_state",
    "state_floats",
    "unflatten_state",
]
