"""Paged KV cache + SSM state-snapshot substrate."""

from .paged import KVPoolSpec, PagedKVPool
from .state_cache import StateCache, StateSpec, flatten_state, state_floats

__all__ = [
    "KVPoolSpec",
    "PagedKVPool",
    "StateCache",
    "StateSpec",
    "flatten_state",
    "state_floats",
]
