"""Prefix *state* caching for SSM/hybrid architectures.

RWKV-6 and RG-LRU have O(1) recurrent state instead of a per-token KV
cache. FASTLIBRA's dependency tree generalizes directly: a KV node becomes a
**state snapshot node** — the recurrent state at a prefix boundary. Matching
a prefix returns the deepest snapshot; decoding resumes from it (no
recompute), exactly like KV reuse. Snapshot nodes are fixed-size, so one
snapshot occupies ``ceil(snapshot_bytes / block_bytes)`` pool blocks.

This is the data plane of the recurrent-state prefix-cache subsystem: the
two-tier (HBM/host) snapshot store, block-addressed by the unified pool's
ids, plus the flatten/unflatten helpers the engine uses to move one batch
row of a model cache pytree in and out of the store. The control plane is
``core.cache_manager`` (``lookup_state`` / ``commit_state``, STATE nodes in
the dependency tree); ``serving.engine`` wires both together so RWKV/RG-LRU
serve with history reuse.

The store is parameterized on the cache dtype: a bf16 model cache snapshots
at bf16 footprint (the earlier forced-f32 layout accounted snapshots at 2×
their true size, distorting pool accounting). Mixed-precision cache leaves
(e.g. RWKV's f32 ``wkv`` inside a bf16 model) are cast to the store dtype on
flatten — bit-exact when the store dtype is the widest leaf dtype, which is
the engine default (f32 store for the f32 CPU engine).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class StateSpec:
    """Flattened recurrent-state snapshot layout."""

    state_elems: int  # elements of one sequence's full-model state snapshot
    block_bytes: int  # unified pool block size (bytes)
    dtype: Any = jnp.float32  # snapshot storage dtype (match the cache dtype)

    @property
    def dtype_bytes(self) -> int:
        return jnp.dtype(self.dtype).itemsize

    @property
    def snapshot_bytes(self) -> int:
        return self.state_elems * self.dtype_bytes

    @property
    def blocks_per_snapshot(self) -> int:
        return -(-self.snapshot_bytes // self.block_bytes)


class StateCache:
    """Two-tier store of flattened state snapshots, block-addressed."""

    def __init__(self, spec: StateSpec, n_hbm_blocks: int, n_host_blocks: int):
        self.spec = spec
        per_block = spec.block_bytes // spec.dtype_bytes
        if per_block < 1:
            raise ValueError("block_bytes smaller than one state element")
        self.per_block = per_block
        self.hbm = jnp.zeros((n_hbm_blocks, per_block), spec.dtype)
        self.host = np.zeros((n_host_blocks, per_block), jnp.dtype(spec.dtype))

    def store(self, block_ids: Sequence[int], flat_state: Array) -> None:
        if not block_ids:
            raise ValueError("cannot store a snapshot into zero blocks")
        capacity = len(block_ids) * self.per_block
        if flat_state.shape[0] > capacity:
            raise ValueError(
                f"snapshot of {flat_state.shape[0]} elements exceeds the "
                f"{capacity}-element capacity of {len(block_ids)} blocks"
            )
        pad = capacity - flat_state.shape[0]
        flat = jnp.pad(flat_state.astype(self.spec.dtype), (0, pad))
        rows = flat.reshape(len(block_ids), self.per_block)
        self.hbm = self.hbm.at[jnp.asarray(list(block_ids))].set(rows)

    def load(self, block_ids: Sequence[int], n_elems: int) -> Array:
        if n_elems > len(block_ids) * self.per_block:
            raise ValueError(
                f"requested {n_elems} elements from {len(block_ids)} blocks "
                f"holding at most {len(block_ids) * self.per_block}"
            )
        rows = jnp.take(self.hbm, jnp.asarray(list(block_ids)), axis=0)
        return rows.reshape(-1)[:n_elems]

    def swap_out(self, hbm_blocks: Sequence[int], host_blocks: Sequence[int]) -> None:
        if not hbm_blocks:  # hollow-node op: structure moved, no payload
            return
        self.host[list(host_blocks)] = np.asarray(
            jnp.take(self.hbm, jnp.asarray(list(hbm_blocks)), axis=0)
        )

    def swap_in(self, host_blocks: Sequence[int], hbm_blocks: Sequence[int]) -> None:
        if not host_blocks:
            return
        rows = jnp.asarray(self.host[list(host_blocks)])
        self.hbm = self.hbm.at[jnp.asarray(list(hbm_blocks))].set(rows)


def _state_items(cache: dict) -> list[tuple[str, Any]]:
    """Deterministic (sorted-key) snapshot leaves of a cache pytree: every
    leaf except the per-row ``len`` counter, which the engine tracks."""
    return [(k, v) for k, v in sorted(cache.items()) if k != "len"]


def _row_shape(leaf) -> tuple[int, ...]:
    """Shape of one batch row of a cache leaf (batch axis is 1 for the
    layer-stacked ``(L, B, ...)`` layout, 0 for flat ``(B,)`` leaves)."""
    return (leaf.shape[:1] + leaf.shape[2:]) if leaf.ndim > 1 else ()


def flat_state_elems(cache: dict) -> int:
    """Elements of one batch row's flattened snapshot. Works on concrete
    arrays and on ``jax.eval_shape`` structs (only shapes are read)."""
    return sum(
        int(np.prod(_row_shape(l), dtype=np.int64)) for _, l in _state_items(cache)
    )


def flatten_state(cache: dict, row: int, dtype=jnp.float32) -> Array:
    """Flatten one batch row of a model cache pytree (minus 'len')."""
    return jnp.concatenate(
        [jnp.ravel(l[:, row] if l.ndim > 1 else l[row]).astype(dtype)
         for _, l in _state_items(cache)]
    )


def unflatten_state(cache: dict, row: int, flat: Array) -> dict:
    """Inverse of :func:`flatten_state`: write ``flat`` back into ``row`` of
    every snapshot leaf (casting to each leaf's dtype) and return the new
    cache pytree. ``cache['len']`` is left untouched — the engine sets it to
    the snapshot's prefix boundary separately."""
    expected = flat_state_elems(cache)
    if flat.shape[0] != expected:
        raise ValueError(
            f"snapshot of {flat.shape[0]} elements does not match the "
            f"{expected}-element cache row layout"
        )
    out = dict(cache)
    off = 0
    for k, leaf in _state_items(cache):
        shape = _row_shape(leaf)
        n = int(np.prod(shape, dtype=np.int64))
        seg = flat[off : off + n].reshape(shape).astype(leaf.dtype)
        out[k] = leaf.at[:, row].set(seg) if leaf.ndim > 1 else leaf.at[row].set(seg)
        off += n
    return out


def state_floats(cfg, batch: int = 1, window: int | None = None) -> int:
    """Element count of one sequence's full recurrent-state snapshot.

    (Historical name; the count is dtype-agnostic — multiply by the store
    dtype's width for bytes.) For RG-LRU hybrids the snapshot must also
    carry the sliding-window K/V of the local-attention layers (``window``
    tokens, default ``cfg.window_size``), or a resumed prefix would attend
    into a zeroed window.
    """
    if cfg.rwkv is not None:
        hd = cfg.rwkv.head_dim
        H = cfg.d_model // hd
        per_layer = H * hd * hd + 2 * cfg.d_model
        return per_layer * cfg.num_layers
    if cfg.rglru is not None:
        pat = cfg.rglru.block_pattern
        n_rec = sum(
            1 for i in range(cfg.num_layers) if pat[i % len(pat)] == "rec"
        )
        n_attn = cfg.num_layers - n_rec
        w = cfg.rglru.lru_width or cfg.d_model
        rec = n_rec * (w + (cfg.rglru.conv_width - 1) * w)
        win = window if window is not None else (cfg.window_size or 0)
        attn = 2 * n_attn * win * cfg.num_kv_heads * cfg.resolved_head_dim
        return rec + attn
    raise ValueError("state caching applies to SSM/hybrid archs only")
