"""Prefix *state* caching for SSM/hybrid architectures (beyond-paper).

RWKV-6 and RG-LRU have O(1) recurrent state instead of a per-token KV
cache. FASTLIBRA's dependency tree generalizes directly: a KV node becomes a
**state snapshot node** — the recurrent state at a prefix boundary. Matching
a prefix returns the deepest snapshot; decoding resumes from it (no
recompute), exactly like KV reuse. Snapshot nodes are fixed-size, so one
snapshot occupies ``ceil(state_bytes / block_bytes)`` pool blocks.

This file provides the host/device snapshot store keyed by pool block ids,
mirroring ``PagedKVPool``'s two-tier layout.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class StateSpec:
    """Flattened recurrent-state snapshot layout."""

    state_floats: int  # total f32 elements of one sequence's full-model state
    block_bytes: int  # unified pool block size (bytes)

    @property
    def blocks_per_snapshot(self) -> int:
        return -(-self.state_floats * 4 // self.block_bytes)


class StateCache:
    """Two-tier store of flattened state snapshots, block-addressed."""

    def __init__(self, spec: StateSpec, n_hbm_blocks: int, n_host_blocks: int):
        self.spec = spec
        per_block = spec.block_bytes // 4
        self.per_block = per_block
        self.hbm = jnp.zeros((n_hbm_blocks, per_block), jnp.float32)
        self.host = np.zeros((n_host_blocks, per_block), np.float32)

    def store(self, block_ids: Sequence[int], flat_state: Array) -> None:
        pad = len(block_ids) * self.per_block - flat_state.shape[0]
        flat = jnp.pad(flat_state, (0, pad))
        rows = flat.reshape(len(block_ids), self.per_block)
        self.hbm = self.hbm.at[jnp.asarray(list(block_ids))].set(rows)

    def load(self, block_ids: Sequence[int], n_floats: int) -> Array:
        rows = jnp.take(self.hbm, jnp.asarray(list(block_ids)), axis=0)
        return rows.reshape(-1)[:n_floats]

    def swap_out(self, hbm_blocks: Sequence[int], host_blocks: Sequence[int]) -> None:
        self.host[list(host_blocks)] = np.asarray(
            jnp.take(self.hbm, jnp.asarray(list(hbm_blocks)), axis=0)
        )

    def swap_in(self, host_blocks: Sequence[int], hbm_blocks: Sequence[int]) -> None:
        rows = jnp.asarray(self.host[list(host_blocks)])
        self.hbm = self.hbm.at[jnp.asarray(list(hbm_blocks))].set(rows)


def flatten_state(cache: dict, row: int) -> Array:
    """Flatten one batch row of a model cache pytree (minus 'len')."""
    leaves = [v for k, v in sorted(cache.items()) if k != "len"]
    return jnp.concatenate(
        [jnp.ravel(l[:, row] if l.ndim > 1 else l[row]).astype(jnp.float32)
         for l in leaves]
    )


def state_floats(cfg, batch: int = 1) -> int:
    """Size (f32 elements) of one sequence's full recurrent state."""
    if cfg.rwkv is not None:
        hd = cfg.rwkv.head_dim
        H = cfg.d_model // hd
        per_layer = H * hd * hd + 2 * cfg.d_model
        return per_layer * cfg.num_layers
    if cfg.rglru is not None:
        pat = cfg.rglru.block_pattern
        n_rec = sum(
            1 for i in range(cfg.num_layers) if pat[i % len(pat)] == "rec"
        )
        w = cfg.rglru.lru_width or cfg.d_model
        return n_rec * (w + (cfg.rglru.conv_width - 1) * w)
    raise ValueError("state caching applies to SSM/hybrid archs only")
