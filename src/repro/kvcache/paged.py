"""Physical paged KV store: two-tier (device + host) block arrays.

The control plane (``repro.core.BlockPool``) hands out integer block ids;
this module maps them to rows of physical arrays:

* HBM tier  — jnp arrays ``(L, n_hbm_blocks, block_size, Hkv, D)`` (k and v)
* host tier — numpy arrays ``(L, n_host_blocks, block_size, Hkv, D)``

Running queries use *dense* per-sequence caches (the model's native layout);
the pool is touched at admission (gather prefix blocks → dense) and at
commit (scatter the new suffix → blocks), mirroring the paper's running-KV /
history-KV split (Fig. 14). Swap ops copy rows between tiers (host↔device
transfers — what PCIe does on the paper's platform).

MLA archs store (latent ‖ k_rope) in the k array with Hkv=1 and
D = kv_lora_rank + rope_dim (v array unused); SSM archs use
``state_cache.StateCache`` instead.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class KVPoolSpec:
    num_layers: int
    block_size: int  # tokens per block
    kv_heads: int
    head_dim: int
    dtype: jnp.dtype = jnp.float32
    use_v: bool = True  # False for MLA (latent-only)

    @property
    def bytes_per_token(self) -> int:
        per = self.num_layers * self.kv_heads * self.head_dim
        per *= 2 if self.use_v else 1
        return per * jnp.dtype(self.dtype).itemsize

    @property
    def block_bytes(self) -> int:
        return self.bytes_per_token * self.block_size


class PagedKVPool:
    """Two-tier physical KV block store."""

    def __init__(self, spec: KVPoolSpec, n_hbm_blocks: int, n_host_blocks: int):
        self.spec = spec
        s = spec
        shape_hbm = (s.num_layers, n_hbm_blocks, s.block_size, s.kv_heads, s.head_dim)
        shape_host = (s.num_layers, n_host_blocks, s.block_size, s.kv_heads, s.head_dim)
        self.k_hbm = jnp.zeros(shape_hbm, s.dtype)
        self.v_hbm = jnp.zeros(shape_hbm, s.dtype) if s.use_v else None
        self.k_host = np.zeros(shape_host, s.dtype)
        self.v_host = np.zeros(shape_host, s.dtype) if s.use_v else None
        self.swap_in_bytes = 0
        self.swap_out_bytes = 0

    # ------------------------------------------------------------- gather
    def gather(self, block_ids: Sequence[int]) -> tuple[Array, Optional[Array]]:
        """HBM blocks → dense (L, T, Hkv, D)."""
        idx = jnp.asarray(list(block_ids), jnp.int32)
        s = self.spec
        k = jnp.take(self.k_hbm, idx, axis=1)  # (L, n, bs, H, D)
        k = k.reshape(s.num_layers, -1, s.kv_heads, s.head_dim)
        v = None
        if self.v_hbm is not None:
            v = jnp.take(self.v_hbm, idx, axis=1).reshape(
                s.num_layers, -1, s.kv_heads, s.head_dim
            )
        return k, v

    # ------------------------------------------------------------ scatter
    def scatter(
        self,
        block_ids: Sequence[int],
        k_dense: Array,  # (L, T, Hkv, D) — T must be len(block_ids)*block_size
        v_dense: Optional[Array] = None,
    ) -> None:
        s = self.spec
        n = len(block_ids)
        idx = jnp.asarray(list(block_ids), jnp.int32)
        kb = k_dense.reshape(s.num_layers, n, s.block_size, s.kv_heads, s.head_dim)
        self.k_hbm = self.k_hbm.at[:, idx].set(kb.astype(s.dtype))
        if self.v_hbm is not None and v_dense is not None:
            vb = v_dense.reshape(s.num_layers, n, s.block_size, s.kv_heads, s.head_dim)
            self.v_hbm = self.v_hbm.at[:, idx].set(vb.astype(s.dtype))

    # --------------------------------------------------------------- swaps
    def swap_out(self, hbm_blocks: Sequence[int], host_blocks: Sequence[int]) -> None:
        """Copy HBM rows to host rows (device→host transfer)."""
        hb = list(hbm_blocks)
        dst = list(host_blocks)
        k_rows = np.asarray(jnp.take(self.k_hbm, jnp.asarray(hb), axis=1))
        self.k_host[:, dst] = k_rows
        if self.v_hbm is not None:
            v_rows = np.asarray(jnp.take(self.v_hbm, jnp.asarray(hb), axis=1))
            self.v_host[:, dst] = v_rows
        self.swap_out_bytes += k_rows.nbytes * (2 if self.v_hbm is not None else 1)

    def swap_in(self, host_blocks: Sequence[int], hbm_blocks: Sequence[int]) -> None:
        """Copy host rows to HBM rows (host→device transfer)."""
        src = list(host_blocks)
        dst = jnp.asarray(list(hbm_blocks), jnp.int32)
        k_rows = jnp.asarray(self.k_host[:, src])
        self.k_hbm = self.k_hbm.at[:, dst].set(k_rows)
        if self.v_hbm is not None:
            v_rows = jnp.asarray(self.v_host[:, src])
            self.v_hbm = self.v_hbm.at[:, dst].set(v_rows)
        self.swap_in_bytes += k_rows.nbytes * (2 if self.v_hbm is not None else 1)
