"""Tracer core: ring-buffered host-side events + Chrome trace export.

Design contract (see package docstring and README §Observability):

* **Host scalars only.** Callers pass plain Python floats/ints/strings.
  The tracer never imports jax/numpy and never forces a device sync, so
  instrumentation inside engine hot paths stays clean under the
  ``host-sync`` lint rule.
* **Timestamp-agnostic.** Callers supply timestamps from their own
  monotonic clock (the engine's ``_now()`` engine-relative seconds, the
  simulator's virtual clock). The tracer only converts to microseconds at
  export time, so engine and sim traces share one timeline convention.
* **No-op fast path.** :data:`NULL_TRACER` is a singleton whose
  ``enabled`` is ``False``; every call site guards with
  ``if tracer.enabled:`` so the disabled cost is one attribute read.
* **Bounded memory.** Events live in a ``collections.deque(maxlen=...)``
  ring buffer; overflow drops the oldest events and bumps
  ``dropped_events`` rather than growing without bound.
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from typing import Any, Dict, List, Optional

# ---------------------------------------------------------------------------
# Event vocabulary — shared by serving/engine.py and sim/simulator.py so the
# two timelines can be diffed event-for-event.
# ---------------------------------------------------------------------------

# Request lifecycle (queue + per-slot tracks).
EV_SUBMIT = "req.submit"
EV_QUEUE = "req.queue"
EV_ADMIT = "req.admit"
EV_PREFILL_CHUNK = "prefill.chunk"
EV_DECODE_STEP = "decode.step"
EV_PREEMPT = "req.preempt"
EV_RESUME = "req.resume"
EV_FINISH = "req.finish"
EV_ABORT = "req.abort"
EV_STEP = "engine.step"
EV_TTFT_ATTRIBUTION = "req.ttft_attribution"
EV_CALIBRATION = "req.ttft_calibration"

# Cache-decision audit log (cache + swapper tracks). "evict" records a
# *decision* (victim, score, competing candidates); swap_out/drop/swap_in
# record the resulting node movement with its cost-model score.
EV_CACHE_ADMIT = "cache.admit"
EV_CACHE_EVICT = "cache.evict"
EV_CACHE_SWAP_IN = "cache.swap_in"
EV_CACHE_SWAP_OUT = "cache.swap_out"
EV_CACHE_DROP = "cache.drop"
EV_CACHE_PREFETCH = "cache.prefetch"
EV_CACHE_COMMIT = "cache.commit"
EV_CACHE_PREEMPT = "cache.preempt"
EV_CACHE_LOAD = "cache.load_new"

# Track (Perfetto thread) names.
TRACK_QUEUE = "queue"
TRACK_ENGINE = "engine"
TRACK_SWAPPER = "swapper"
TRACK_CACHE = "cache"

# TTFT attribution categories (exact additive partition of
# [submit_time, first_token_time]; see serving/request.py).
ATTRIB_CATEGORIES = (
    "queue",
    "lora_load",
    "swap_in",
    "recompute",
    "compute",
    "stall",
    "other",
)

_PH_SPAN = "X"
_PH_INSTANT = "i"
_PH_COUNTER = "C"


def slot_track(slot: int) -> str:
    """Track name for decode slot ``slot`` (one Perfetto row per slot)."""
    return f"slot{slot}"


def trace_env_enabled() -> bool:
    """True when tracing is armed process-wide via ``REPRO_TRACE=1``."""
    return os.environ.get("REPRO_TRACE", "0") == "1"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded event. ``ts``/``dur`` are caller-clock seconds."""

    phase: str  # "X" span | "i" instant | "C" counter sample
    name: str
    track: str
    ts: float
    dur: float = 0.0
    args: Optional[Dict[str, Any]] = None


class Tracer:
    """Ring-buffered span/instant/counter recorder with Chrome export."""

    enabled = True

    def __init__(self, capacity: int = 200_000):
        self.events: deque = deque(maxlen=capacity)
        self.capacity = capacity
        self.dropped_events = 0
        # Aggregate registries, independent of the ring buffer (never
        # dropped): monotonically increasing counts and last-value gauges.
        self.counts: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}

    # -- recording ----------------------------------------------------------

    def _push(self, ev: TraceEvent) -> None:
        if len(self.events) == self.capacity:
            self.dropped_events += 1
        self.events.append(ev)

    def span(self, track: str, name: str, t0: float, t1: float, **args: Any) -> None:
        """Record a complete span [t0, t1] on ``track``."""
        self._push(TraceEvent(_PH_SPAN, name, track, t0, max(0.0, t1 - t0), args or None))

    def instant(self, track: str, name: str, t: float, **args: Any) -> None:
        """Record a point event at ``t`` on ``track``."""
        self._push(TraceEvent(_PH_INSTANT, name, track, t, 0.0, args or None))

    def counter(self, name: str, t: float, **series: float) -> None:
        """Record a counter sample (one Perfetto counter track per name)."""
        self._push(TraceEvent(_PH_COUNTER, name, name, t, 0.0, dict(series)))

    def audit(self, name: str, t: float, **fields: Any) -> None:
        """Record a cache-decision audit event (instant on the cache track)."""
        self.count(name)
        self._push(TraceEvent(_PH_INSTANT, name, TRACK_CACHE, t, 0.0, fields or None))

    def count(self, name: str, n: int = 1) -> None:
        """Bump an aggregate counter (registry, not the ring buffer)."""
        self.counts[name] = self.counts.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        """Set an aggregate gauge to its latest value."""
        self.gauges[name] = value

    def clear(self) -> None:
        self.events.clear()
        self.dropped_events = 0
        self.counts.clear()
        self.gauges.clear()

    # -- queries ------------------------------------------------------------

    def named(self, name: str) -> List[TraceEvent]:
        """All buffered events with the given name, in record order."""
        return [ev for ev in self.events if ev.name == name]

    # -- export -------------------------------------------------------------

    def export_chrome(self) -> Dict[str, Any]:
        """Render the buffer as a Chrome trace-event JSON object.

        Loads directly in Perfetto / chrome://tracing: one pid (0) with one
        named thread per track, timestamps in microseconds.
        """
        pid = 0
        tids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = []

        def tid(track: str) -> int:
            t = tids.get(track)
            if t is None:
                t = len(tids)
                tids[track] = t
                out.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": pid,
                        "tid": t,
                        "args": {"name": track},
                    }
                )
            return t

        for ev in self.events:
            rec: Dict[str, Any] = {
                "name": ev.name,
                "ph": ev.phase,
                "pid": pid,
                "tid": tid(ev.track),
                "ts": ev.ts * 1e6,
            }
            if ev.phase == _PH_SPAN:
                rec["dur"] = ev.dur * 1e6
            elif ev.phase == _PH_INSTANT:
                rec["s"] = "t"  # thread-scoped instant
            if ev.args:
                rec["args"] = ev.args
            out.append(rec)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.obs libra-trace",
                "droppedEvents": self.dropped_events,
                "counts": dict(self.counts),
                "gauges": dict(self.gauges),
            },
        }

    def dump(self, path: str) -> None:
        """Write the Chrome trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.export_chrome(), f)


class NullTracer(Tracer):
    """Disabled tracer: every recording call is a no-op.

    Call sites additionally guard with ``if tracer.enabled:`` so the
    disabled cost is one attribute read and no argument evaluation.
    """

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def _push(self, ev: TraceEvent) -> None:  # pragma: no cover - guarded out
        pass

    def count(self, name: str, n: int = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass


NULL_TRACER = NullTracer()
