"""Summarize a dumped libra-trace file.

Usage::

    python -m repro.obs.report trace.json [--top N]

Reads Chrome trace-event JSON produced by ``Tracer.dump`` (or any
conforming file) and prints:

* span histograms — per span name: count, total/mean/p50/p99 duration;
* the cache audit summary — event counts per decision kind and the top-N
  evicted/demoted nodes by total bytes moved, with their last cost-model
  score;
* the TTFT attribution table — per finished request, the additive
  queue/lora_load/swap_in/recompute/compute/stall/other breakdown and its
  reconciliation against measured TTFT;
* estimate_ttft calibration — MAE and signed bias of predicted vs actual.
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from .tracer import (
    ATTRIB_CATEGORIES,
    EV_CACHE_DROP,
    EV_CACHE_EVICT,
    EV_CACHE_SWAP_OUT,
    EV_CALIBRATION,
    EV_TTFT_ATTRIBUTION,
)

_EVICT_EVENTS = (EV_CACHE_EVICT, EV_CACHE_SWAP_OUT, EV_CACHE_DROP)


def _p(vals: List[float], q: float) -> float:
    if not vals:
        return 0.0
    vals = sorted(vals)
    return vals[min(len(vals) - 1, int(q * len(vals)))]


def load(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents list)")
    return events


def span_histograms(events: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Per-name duration stats over all complete ("X") events, in ms."""
    by_name: Dict[str, List[float]] = {}
    for ev in events:
        if ev.get("ph") == "X":
            by_name.setdefault(ev["name"], []).append(ev.get("dur", 0.0) / 1e3)
    return {
        name: {
            "count": float(len(durs)),
            "total_ms": sum(durs),
            "mean_ms": sum(durs) / len(durs),
            "p50_ms": _p(durs, 0.5),
            "p99_ms": _p(durs, 0.99),
        }
        for name, durs in sorted(by_name.items())
    }


def audit_summary(events: List[Dict[str, Any]], top: int = 10) -> Dict[str, Any]:
    """Counts per audit event + top evicted nodes by total bytes moved."""
    counts: Dict[str, int] = {}
    nodes: Dict[Any, Dict[str, Any]] = {}
    for ev in events:
        name = ev.get("name", "")
        if not name.startswith("cache."):
            continue
        counts[name] = counts.get(name, 0) + 1
        args = ev.get("args") or {}
        if name in _EVICT_EVENTS and "node_id" in args:
            rec = nodes.setdefault(
                args["node_id"],
                {"node_id": args["node_id"], "kind": args.get("kind"), "evictions": 0, "bytes": 0, "last_score": None},
            )
            rec["evictions"] += 1
            rec["bytes"] += int(args.get("bytes", 0))
            if "score" in args:
                rec["last_score"] = args["score"]
            rec["kind"] = args.get("kind", rec["kind"])
    ranked = sorted(nodes.values(), key=lambda r: (-r["bytes"], r["node_id"]))
    return {"counts": counts, "top_evicted": ranked[:top]}


def attribution_rows(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """One row per finished request: breakdown + TTFT reconciliation."""
    rows = []
    for ev in events:
        if ev.get("name") != EV_TTFT_ATTRIBUTION:
            continue
        args = ev.get("args") or {}
        row = {"rid": args.get("rid")}
        total = 0.0
        for cat in ATTRIB_CATEGORIES:
            v = float(args.get(cat, 0.0))
            row[cat] = v
            total += v
        row["sum"] = total
        row["ttft"] = float(args.get("ttft", 0.0))
        row["resid"] = row["ttft"] - total
        rows.append(row)
    return rows


def calibration(events: List[Dict[str, Any]]) -> Dict[str, float]:
    """MAE/bias of estimate_ttft's predictions against measured TTFT."""
    errs = []
    for ev in events:
        if ev.get("name") != EV_CALIBRATION:
            continue
        args = ev.get("args") or {}
        if "predicted" in args and "actual" in args:
            errs.append(float(args["predicted"]) - float(args["actual"]))
    if not errs:
        return {"n": 0, "mae_s": 0.0, "bias_s": 0.0}
    return {
        "n": len(errs),
        "mae_s": sum(abs(e) for e in errs) / len(errs),
        "bias_s": sum(errs) / len(errs),
    }


def render(path: str, top: int = 10) -> str:
    events = load(path)
    lines = [f"libra-trace report: {path} ({len(events)} events)", ""]

    lines.append("== span histograms (ms) ==")
    hists = span_histograms(events)
    if hists:
        lines.append(f"{'span':24s} {'count':>7s} {'mean':>9s} {'p50':>9s} {'p99':>9s} {'total':>10s}")
        for name, h in hists.items():
            lines.append(
                f"{name:24s} {int(h['count']):7d} {h['mean_ms']:9.3f} "
                f"{h['p50_ms']:9.3f} {h['p99_ms']:9.3f} {h['total_ms']:10.2f}"
            )
    else:
        lines.append("(no spans)")

    lines.append("")
    lines.append("== cache audit ==")
    audit = audit_summary(events, top=top)
    for name, n in sorted(audit["counts"].items()):
        lines.append(f"{name:24s} {n:7d}")
    if audit["top_evicted"]:
        lines.append(f"top {len(audit['top_evicted'])} evicted nodes (by bytes moved):")
        lines.append(f"{'node':>8s} {'kind':14s} {'evictions':>9s} {'bytes':>12s} {'last_score':>12s}")
        for rec in audit["top_evicted"]:
            score = "-" if rec["last_score"] is None else f"{rec['last_score']:.4g}"
            lines.append(
                f"{rec['node_id']!s:>8s} {str(rec['kind']):14s} "
                f"{rec['evictions']:9d} {rec['bytes']:12d} {score:>12s}"
            )
    else:
        lines.append("(no evictions recorded)")

    lines.append("")
    lines.append("== TTFT attribution (ms) ==")
    rows = attribution_rows(events)
    if rows:
        hdr = f"{'rid':>6s} " + " ".join(f"{c:>9s}" for c in ATTRIB_CATEGORIES)
        lines.append(hdr + f" {'sum':>9s} {'ttft':>9s} {'resid':>9s}")
        for row in rows:
            cells = " ".join(f"{row[c] * 1e3:9.3f}" for c in ATTRIB_CATEGORIES)
            lines.append(
                f"{row['rid']!s:>6s} {cells} {row['sum'] * 1e3:9.3f} "
                f"{row['ttft'] * 1e3:9.3f} {row['resid'] * 1e3:9.3f}"
            )
        n = len(rows)
        means = " ".join(f"{sum(r[c] for r in rows) / n * 1e3:9.3f}" for c in ATTRIB_CATEGORIES)
        lines.append(f"{'mean':>6s} {means}")
    else:
        lines.append("(no finished requests with attribution)")

    lines.append("")
    lines.append("== estimate_ttft calibration ==")
    cal = calibration(events)
    lines.append(f"n={cal['n']} mae={cal['mae_s'] * 1e3:.3f}ms bias={cal['bias_s'] * 1e3:+.3f}ms")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a libra-trace Chrome trace-event JSON file.",
    )
    ap.add_argument("trace", help="path to a trace dumped via --trace-out / Tracer.dump")
    ap.add_argument("--top", type=int, default=10, help="rows in the top-evicted table")
    args = ap.parse_args(argv)
    try:
        print(render(args.trace, top=args.top))
    except BrokenPipeError:  # e.g. piped into head
        pass
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
