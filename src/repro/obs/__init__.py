"""libra-trace: span-based engine tracing + cache-decision audit log.

A zero-dependency (stdlib-only) observability layer for the serving stack:

* :class:`Tracer` — monotonic-clock spans, instants and counter series in a
  ring-buffered host-side event log, plus named counter/gauge registries.
  Every recorded value is a plain Python scalar: the tracer never touches a
  device array, so instrumented hot paths stay clean under the ``host-sync``
  libra-lint rule and armed tracing adds no device round trips.
* :data:`NULL_TRACER` — the module-level no-op fast path. With tracing
  disabled every instrumentation site is one attribute read
  (``tracer.enabled`` is ``False``) and the serving hot loop is unchanged —
  the CI overhead gate pins compile counts and token streams identical.
* Arming: ``REPRO_TRACE=1`` (same env-override pattern as
  ``REPRO_SCHEDULE_MODE``) or ``EngineConfig(trace=True)`` /
  ``SimConfig(trace=True)`` per engine.
* Export: :meth:`Tracer.export_chrome` emits Chrome trace-event JSON that
  loads directly in Perfetto (one track per decode slot, the admission
  queue, the swapper, and the cache audit log); ``python -m
  repro.obs.report trace.json`` summarizes a dumped trace (top evicted
  nodes, TTFT attribution table, span histograms, estimate_ttft
  calibration).

The event vocabulary (``EV_*`` / ``TRACK_*``) is shared by the JAX engine
and the discrete-event simulator so engine-vs-sim timelines diff cleanly.
See README.md §Observability.
"""

from .tracer import (
    ATTRIB_CATEGORIES,
    EV_ABORT,
    EV_ADMIT,
    EV_CACHE_ADMIT,
    EV_CACHE_COMMIT,
    EV_CACHE_DROP,
    EV_CACHE_EVICT,
    EV_CACHE_LOAD,
    EV_CACHE_PREEMPT,
    EV_CACHE_PREFETCH,
    EV_CACHE_SWAP_IN,
    EV_CACHE_SWAP_OUT,
    EV_CALIBRATION,
    EV_DECODE_STEP,
    EV_FINISH,
    EV_PREEMPT,
    EV_PREFILL_CHUNK,
    EV_QUEUE,
    EV_RESUME,
    EV_STEP,
    EV_SUBMIT,
    EV_TTFT_ATTRIBUTION,
    NULL_TRACER,
    TRACK_CACHE,
    TRACK_ENGINE,
    TRACK_QUEUE,
    TRACK_SWAPPER,
    NullTracer,
    Tracer,
    slot_track,
    trace_env_enabled,
)

__all__ = [
    "ATTRIB_CATEGORIES",
    "EV_ABORT",
    "EV_ADMIT",
    "EV_CACHE_ADMIT",
    "EV_CACHE_COMMIT",
    "EV_CACHE_DROP",
    "EV_CACHE_EVICT",
    "EV_CACHE_LOAD",
    "EV_CACHE_PREEMPT",
    "EV_CACHE_PREFETCH",
    "EV_CACHE_SWAP_IN",
    "EV_CACHE_SWAP_OUT",
    "EV_CALIBRATION",
    "EV_DECODE_STEP",
    "EV_FINISH",
    "EV_PREEMPT",
    "EV_PREFILL_CHUNK",
    "EV_QUEUE",
    "EV_RESUME",
    "EV_STEP",
    "EV_SUBMIT",
    "EV_TTFT_ATTRIBUTION",
    "NULL_TRACER",
    "NullTracer",
    "TRACK_CACHE",
    "TRACK_ENGINE",
    "TRACK_QUEUE",
    "TRACK_SWAPPER",
    "Tracer",
    "slot_track",
    "trace_env_enabled",
]
