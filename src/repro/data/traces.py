"""Synthetic workload generation matching the paper's three scenarios.

The original datasets (LMSYS-33k, OPUS-100, Taskmaster) and the Microsoft
Azure Function trace are not redistributable offline; this module generates
statistically-matched synthetic traces with the same knobs the paper uses:

* **chatbot**     — multi-turn conversations, LoRA per conversation sampled
  from a Zipf popularity (LMSYS model-popularity-like), medium turns.
* **translation** — single-turn queries, many LoRAs (language pairs), and a
  *time-varying* hot set (the paper observes 41 → 75 active LoRAs mid-trace,
  which is what breaks static HBM partitions).
* **agent**       — fewest LoRAs, the longest conversations (Taskmaster-like),
  stressing history-KV reuse (where S-LoRA collapses).

Arrival timing follows an Azure-Function-like bursty process: per-interval
rates drawn from a lognormal modulation of the base rate (MAFT burstiness),
Poisson arrivals within an interval.

Large-LoRA-count distributions for the paper's §6.9: uniform / distinct
(round-robin) / skewed-σ (Gaussian over adapter index).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterator, Optional


@dataclasses.dataclass
class SimQuery:
    arrival: float
    conversation_id: int
    lora_id: str
    history: tuple[int, ...]  # tokens reusable from previous turns
    new_tokens: tuple[int, ...]  # this turn's fresh prompt tokens
    output_tokens: tuple[int, ...]  # the (deterministic) generated reply
    # leading prompt tokens that are adapter-INDEPENDENT (a product-wide
    # system prompt shared by every adapter): computed with the adapter
    # inactive, cacheable once on the shared trunk. 0 = legacy traces.
    shared_prefix_len: int = 0
    # SLO tier: higher = more latency-sensitive (0 = batch). Admission is
    # priority-strict; only strictly-lower tiers are preemptable.
    priority: int = 0
    # absolute first-token deadline on the trace clock (None = no SLO)
    deadline: Optional[float] = None

    @property
    def prompt(self) -> tuple[int, ...]:
        return self.history + self.new_tokens

    @property
    def output_len(self) -> int:
        return len(self.output_tokens)

    @property
    def full(self) -> tuple[int, ...]:
        return self.history + self.new_tokens + self.output_tokens


@dataclasses.dataclass
class TraceConfig:
    scenario: str = "chatbot"  # chatbot | translation | agent
    n_loras: int = 50
    duration: float = 600.0
    mean_qps: float = 2.0
    seed: int = 0
    burstiness: float = 0.6  # lognormal sigma of the per-interval rate
    interval: float = 30.0  # rate-modulation interval (s)
    distribution: str = "zipf"  # zipf | uniform | distinct | skewed
    skew_sigma: float = 100.0  # for skewed-x
    # cross-adapter shared system prompt: every conversation's prompt opens
    # with this many adapter-independent tokens (one product-wide system
    # prompt common to ALL adapters), and each query carries the matching
    # shared_prefix_len. 0 (default) keeps traces byte-identical to before.
    shared_system_prompt_len: int = 0
    # mixed-SLO tiering: this fraction of conversations is interactive
    # (priority 1) with a first-token deadline of arrival +
    # interactive_ttft_slo; the rest stay batch tier (priority 0, no
    # deadline). 0.0 (default) keeps traces byte-identical to before.
    interactive_fraction: float = 0.0
    interactive_ttft_slo: float = 1.0


_SCENARIOS = {
    #              turns      user_toks   out_toks  gap(s)  template
    "chatbot": dict(turns=(1, 8), user=(30, 220), out=(40, 260), gap=20.0,
                    template=16),
    "translation": dict(turns=(1, 1), user=(20, 120), out=(20, 140), gap=0.0,
                        template=48),
    "agent": dict(turns=(4, 16), user=(20, 120), out=(30, 160), gap=12.0,
                  template=24),
}


def _conv_tokens(conv_id: int, start: int, n: int) -> tuple[int, ...]:
    """Unique-but-deterministic token ids for conversation content."""
    base = (conv_id + 1) * 1_000_000
    return tuple(base + start + i for i in range(n))


def _template_tokens(lora_idx: int, n: int) -> tuple[int, ...]:
    """Per-LoRA shared system/template prefix (e.g. the translation
    instruction) — reused across all queries of that adapter, which is what
    cross-query prefix caching exploits in single-turn scenarios."""
    base = -(lora_idx + 1) * 10_000  # negative range: never collides with convs
    return tuple(base - i for i in range(n))


def _shared_system_tokens(n: int) -> tuple[int, ...]:
    """The product-wide system prompt common to ALL adapters — one token
    range far below every per-LoRA template and conversation range."""
    base = -(10**9)
    return tuple(base - i for i in range(n))


class _LoraSampler:
    def __init__(self, cfg: TraceConfig, rng: random.Random):
        self.cfg = cfg
        self.rng = rng
        self._rr = 0
        if cfg.distribution == "zipf":
            w = [1.0 / (i + 1) ** 0.9 for i in range(cfg.n_loras)]
            tot = sum(w)
            self.weights = [x / tot for x in w]
        elif cfg.distribution == "skewed":
            mid = cfg.n_loras / 2
            w = [math.exp(-((i - mid) ** 2) / (2 * cfg.skew_sigma**2))
                 for i in range(cfg.n_loras)]
            tot = sum(w)
            self.weights = [x / tot for x in w]
        else:
            self.weights = None

    def sample(self, t: float) -> int:
        cfg = self.cfg
        if cfg.distribution == "distinct":
            self._rr = (self._rr + 1) % cfg.n_loras
            return self._rr
        if cfg.distribution == "uniform":
            return self.rng.randrange(cfg.n_loras)
        idx = self.rng.choices(range(cfg.n_loras), weights=self.weights)[0]
        if cfg.scenario == "translation":
            # time-varying hot set: rotate the popularity ranking so the
            # active-LoRA working set drifts (the paper's 41→75 effect)
            shift = int(t / max(1.0, cfg.duration) * cfg.n_loras * 0.5)
            idx = (idx + shift) % cfg.n_loras
        return idx


def generate_trace(cfg: TraceConfig) -> list[SimQuery]:
    rng = random.Random(cfg.seed)
    sc = _SCENARIOS[cfg.scenario]
    sampler = _LoraSampler(cfg, rng)
    queries: list[SimQuery] = []
    conv_counter = 0
    t = 0.0
    while t < cfg.duration:
        # Azure-like bursty rate for this interval
        rate = cfg.mean_qps * math.exp(
            rng.gauss(-cfg.burstiness**2 / 2, cfg.burstiness)
        )
        end = min(cfg.duration, t + cfg.interval)
        # Poisson arrivals in [t, end)
        tt = t
        while True:
            tt += rng.expovariate(max(rate, 1e-6))
            if tt >= end:
                break
            conv_counter += 1
            conv_id = conv_counter
            lora = sampler.sample(tt)
            # SLO tier per conversation (every turn inherits it): the guard
            # short-circuits so interactive_fraction=0 draws nothing from
            # the rng stream and legacy traces stay byte-identical
            interactive = (cfg.interactive_fraction > 0
                           and rng.random() < cfg.interactive_fraction)
            n_turns = rng.randint(*sc["turns"])
            cursor = 0
            shared = _shared_system_tokens(cfg.shared_system_prompt_len)
            history: tuple[int, ...] = (
                shared + _template_tokens(lora, sc["template"]))
            arr = tt
            for turn in range(n_turns):
                user_n = rng.randint(*sc["user"])
                out_n = rng.randint(*sc["out"])
                new = _conv_tokens(conv_id, cursor, user_n)
                cursor += user_n
                out = _conv_tokens(conv_id, cursor, out_n)
                cursor += out_n
                queries.append(
                    SimQuery(
                        arrival=arr,
                        conversation_id=conv_id,
                        lora_id=f"lora-{lora}",
                        history=history,
                        new_tokens=new,
                        output_tokens=out,
                        shared_prefix_len=len(shared),
                        priority=1 if interactive else 0,
                        deadline=(arr + cfg.interactive_ttft_slo
                                  if interactive else None),
                    )
                )
                history = history + new + out
                arr += rng.expovariate(1.0 / max(sc["gap"], 1e-6)) if sc["gap"] else 0.0
                if arr >= cfg.duration:
                    break
        t = end
    queries.sort(key=lambda q: q.arrival)
    return queries


def trace_stats(queries: list[SimQuery]) -> dict:
    if not queries:
        return {}
    loras = {q.lora_id for q in queries}
    return {
        "n_queries": len(queries),
        "n_loras_used": len(loras),
        "avg_prompt": sum(len(q.prompt) for q in queries) / len(queries),
        "avg_history": sum(len(q.history) for q in queries) / len(queries),
        "avg_output": sum(q.output_len for q in queries) / len(queries),
        "duration": queries[-1].arrival,
    }
