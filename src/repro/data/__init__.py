"""Synthetic workload traces matched to the paper's three scenarios."""

from .traces import SimQuery, TraceConfig, generate_trace, trace_stats

__all__ = ["SimQuery", "TraceConfig", "generate_trace", "trace_stats"]
