"""SGMV — segmented/gathered multi-LoRA matmul as a Pallas TPU kernel.

Punica/S-LoRA implement SGMV with CUDA warp-level gathers. The TPU adaptation
(README.md §Kernels) moves the gather into the **BlockSpec index map**: the
adapter id of each sequence is scalar-prefetched, and the A/B weight blocks
for grid step ``(b, s, o)`` are fetched HBM→VMEM directly from slot
``ids[b]`` of the stacked adapter tensors — the MXU then runs dense
(tokens×r)·(r×d) tiles. Ragged segments become per-sequence grid rows
(continuous batching keeps one adapter per sequence), so no warp shuffle
analogue is needed.

``fused_sgmv`` folds the base projection into the same kernel: one grid step
computes ``x·W + scale·(x·A)·B`` for its (token, out) tile, so the activation
tile makes exactly one trip HBM→VMEM per (token, out) block instead of one
for the base matmul and another for the LoRA shrink/expand pass. Rows with a
negative adapter id (shared-prefix spans run with the adapter inactive) keep
the base term and zero the delta inside the kernel.

Tiling: token tile ``bs`` × out tile ``bo`` with the full ``d_in`` and rank
``r`` resident (r ≤ 64, d_in ≤ 8192 ⇒ ≤ 2 MB VMEM per operand at bf16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _sgmv_kernel(ids_ref, x_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[0]  # (bs, d_in)
    a = a_ref[0]  # (d_in, r)
    b = b_ref[0]  # (r, bo)
    h = jnp.dot(x, a, preferred_element_type=jnp.float32)
    out = jnp.dot(h, b.astype(jnp.float32), preferred_element_type=jnp.float32)
    o_ref[0] = (out * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "block_o", "interpret")
)
def sgmv(
    x: Array,  # (B, S, d_in)
    lora_a: Array,  # (N, d_in, r)
    lora_b: Array,  # (N, r, d_out)
    adapter_ids: Array,  # (B,) int32
    *,
    scale: float = 1.0,
    block_s: int = 128,
    block_o: int = 128,
    interpret: bool = False,
) -> Array:
    B, S, d_in = x.shape
    N, _, r = lora_a.shape
    d_out = lora_b.shape[-1]
    bs = min(block_s, S)
    bo = min(block_o, d_out)
    grid = (B, pl.cdiv(S, bs), pl.cdiv(d_out, bo))
    # a NEGATIVE id marks a base-model row (shared-prefix span computed with
    # the adapter inactive — see models.common.lora_delta): clamp so the
    # prefetch-gathered BlockSpec index stays in range, then zero the row's
    # delta after the call. Parity with the jnp reference is tested.
    live = adapter_ids >= 0
    adapter_ids = jnp.maximum(adapter_ids, 0)
    out = pl.pallas_call(
        functools.partial(_sgmv_kernel, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bs, d_in), lambda b, s, o, ids: (b, s, 0)),
                pl.BlockSpec((1, d_in, r), lambda b, s, o, ids: (ids[b], 0, 0)),
                pl.BlockSpec((1, r, bo), lambda b, s, o, ids: (ids[b], 0, o)),
            ],
            out_specs=pl.BlockSpec((1, bs, bo), lambda b, s, o, ids: (b, s, o)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(adapter_ids, x, lora_a, lora_b)
    return out * live.astype(out.dtype)[:, None, None]


def _fused_sgmv_kernel(
    ids_ref,  # scalar prefetch: (B,) int32 (raw — may be negative)
    x_ref,  # (1, bs, d_in)
    w_ref,  # (d_in, bo)
    a_ref,  # (1, d_in, r)
    b_ref,  # (1, r, bo)
    o_ref,  # (1, bs, bo)
    *,
    scale: float,
):
    b = pl.program_id(0)
    x = x_ref[0]  # (bs, d_in) — read once, feeds base AND shrink
    base = jnp.dot(
        x, w_ref[...], preferred_element_type=jnp.float32
    )  # (bs, bo)
    h = jnp.dot(x, a_ref[0], preferred_element_type=jnp.float32)  # (bs, r)
    delta = jnp.dot(
        h, b_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32
    )  # (bs, bo)
    # negative id ⇒ base-model row: keep x·W, drop the adapter delta
    live = (ids_ref[b] >= 0).astype(jnp.float32)
    o_ref[0] = (base + (scale * live) * delta).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_s", "block_o", "interpret")
)
def fused_sgmv(
    x: Array,  # (B, S, d_in)
    w: Array,  # (d_in, d_out) — shared base projection
    lora_a: Array,  # (N, d_in, r)
    lora_b: Array,  # (N, r, d_out)
    adapter_ids: Array,  # (B,) int32 — negative marks a base-model row
    *,
    scale: float = 1.0,
    block_s: int = 128,
    block_o: int = 128,
    interpret: bool = False,
) -> Array:
    """Fused base + LoRA projection: ``x·W + scale·(x·A[id])·B[id]``.

    One kernel, one pass over each activation tile per (token, out) block —
    the LoRA path adds two small MXU ops on the already-resident tile rather
    than a second kernel launch re-streaming ``x`` from HBM.
    """
    B, S, d_in = x.shape
    N, _, r = lora_a.shape
    d_out = w.shape[-1]
    bs = min(block_s, S)
    bo = min(block_o, d_out)
    grid = (B, pl.cdiv(S, bs), pl.cdiv(d_out, bo))
    out = pl.pallas_call(
        functools.partial(_fused_sgmv_kernel, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bs, d_in), lambda b, s, o, ids: (b, s, 0)),
                pl.BlockSpec((d_in, bo), lambda b, s, o, ids: (0, o)),
                # clamp negative ids in the index map only — the kernel reads
                # the raw id to decide whether the delta survives
                pl.BlockSpec(
                    (1, d_in, r),
                    lambda b, s, o, ids: (jnp.maximum(ids[b], 0), 0, 0),
                ),
                pl.BlockSpec(
                    (1, r, bo),
                    lambda b, s, o, ids: (jnp.maximum(ids[b], 0), 0, o),
                ),
            ],
            out_specs=pl.BlockSpec((1, bs, bo), lambda b, s, o, ids: (b, s, o)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, d_out), x.dtype),
        interpret=interpret,
    )(adapter_ids.astype(jnp.int32), x, w, lora_a, lora_b)
    return out
