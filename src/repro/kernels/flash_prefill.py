"""Causal flash attention (prefill) as a Pallas TPU kernel.

Standard two-level tiling: grid (B, H, q_blocks, kv_blocks); the kv-block
dimension is innermost/sequential, carrying flash running statistics in VMEM
scratch. GQA is handled in the index map (kv head = q head // G) so KV tiles
are fetched once per group, not per q head. Blocks above the causal diagonal
contribute nothing and are masked (TPU grids cannot be ragged; the masked
blocks are the price of a static grid — see EXPERIMENTS.md §Perf for the
block-skip optimization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    acc_ref,  # (bq, D) f32
    m_ref,  # (bq, 1) f32
    l_ref,  # (bq, 1) f32
    *,
    block_q: int,
    block_k: int,
    kv_blocks: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    @pl.when(j * block_k <= i * block_q + block_q - 1)  # skip above-diagonal
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        D = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
            jnp.float32(D)
        )
        s = jnp.where(k_idx <= q_idx, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(j == kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_prefill(
    q: Array,  # (B, H, S, D)
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    kv_blocks = pl.cdiv(S, bk)
    grid = (B, H, pl.cdiv(S, bq), kv_blocks)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=bq, block_k=bk, kv_blocks=kv_blocks
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
