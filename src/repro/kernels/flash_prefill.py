"""Causal flash attention (prefill) as a Pallas TPU kernel.

Block-skip design (README.md §Kernels): instead of a rectangular
``(q_blocks, kv_blocks)`` grid whose above-diagonal blocks are DMA'd and then
masked away, the (q, kv) block pairs that intersect the causal triangle are
flattened into ONE sequential grid axis. The schedule — which q block, which
kv block, and whether this step finalizes its q row — is computed on the host
from the static shapes and **scalar-prefetched**, so the BlockSpec index maps
steer each step's DMA straight to a live block. Fully-masked blocks are never
fetched and never stepped: for S ≫ block size this halves KV bytes moved.

GQA is handled in the index map (kv head = q head // G) so KV tiles are
fetched once per group, not per q head. Flash running statistics (m, l, acc)
live in VMEM scratch and carry across the sequential flat axis; each q row's
segment starts at its kv block 0 (init) and ends at its diagonal block
(finalize flag).

``flash_prefill_ragged`` additionally scalar-prefetches per-row true lengths:
padded bucket rows clamp their q/kv block indices to the last live block, and
Pallas skips the DMA when an index map returns the same block as the previous
step — so the power-of-two padding tail of a bucketed prefill costs neither
bandwidth nor MXU flops (compute is ``pl.when``-guarded on the same bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _tri_schedule(
    q_blocks: int, kv_blocks: int, block_q: int, block_k: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten the lower-triangular (q, kv) block pairs into one grid axis.

    Returns int32 arrays ``rows[t]`` (q block), ``cols[t]`` (kv block) and
    ``lasts[t]`` (1 on the final — diagonal — kv block of each q row, where
    the kernel normalizes and writes the output block).
    """
    rows: list[int] = []
    cols: list[int] = []
    lasts: list[int] = []
    for i in range(q_blocks):
        need = min(kv_blocks, (i * block_q + block_q - 1) // block_k + 1)
        for j in range(need):
            rows.append(i)
            cols.append(j)
            lasts.append(1 if j == need - 1 else 0)
    return (
        np.asarray(rows, np.int32),
        np.asarray(cols, np.int32),
        np.asarray(lasts, np.int32),
    )


def _flash_body(q, k, v, row_mask, k_valid, m_ref, l_ref, acc_ref):
    """One flash block update: online-softmax accumulate of (q·kᵀ)·v.

    ``row_mask`` is the (bq, bk) validity of each (query, key) pair; masked
    probabilities are zeroed explicitly so a fully-masked row contributes
    nothing (l stays 0 → the finalize guard emits zeros, not mean(V)).
    ``k_valid`` is the (bk, 1) per-key validity: V rows past it are zeroed
    before the dot because 0·garbage is not 0 when the out-of-bounds block
    tail reads back NaN/inf — zeroed p alone does not protect the sum."""
    D = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(D)
    )
    s = jnp.where(row_mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    p = jnp.where(row_mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, jnp.where(k_valid, v, 0.0), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur


def _flash_kernel(
    rows_ref,  # scalar prefetch: (T,) int32 — q block per flat step
    cols_ref,  # scalar prefetch: (T,) int32 — kv block per flat step
    lasts_ref,  # scalar prefetch: (T,) int32 — 1 on each row's final step
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    acc_ref,  # (bq, D) f32
    m_ref,  # (bq, 1) f32
    l_ref,  # (bq, 1) f32
    *,
    block_q: int,
    block_k: int,
    seq_len: int,
):
    t = pl.program_id(2)
    i = rows_ref[t]
    j = cols_ref[t]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Every scheduled block intersects the causal triangle, so the update
    # runs unconditionally; only the per-element mask remains (the seq_len
    # bound covers the padded tail when S is not a block multiple).
    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    _flash_body(
        q_ref[0, 0].astype(jnp.float32),
        k_ref[0, 0].astype(jnp.float32),
        v_ref[0, 0].astype(jnp.float32),
        (k_idx <= q_idx) & (k_idx < seq_len),
        k_idx.T < seq_len,
        m_ref,
        l_ref,
        acc_ref,
    )

    @pl.when(lasts_ref[t] == 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_prefill(
    q: Array,  # (B, H, S, D)
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    rows, cols, lasts = _tri_schedule(pl.cdiv(S, bq), pl.cdiv(S, bk), bq, bk)
    grid = (B, H, len(rows))
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=bq, block_k=bk, seq_len=S),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bq, D), lambda b, h, t, r, c, f: (b, h, r[t], 0)
                ),
                pl.BlockSpec(
                    (1, 1, bk, D),
                    lambda b, h, t, r, c, f: (b, h // G, c[t], 0),
                ),
                pl.BlockSpec(
                    (1, 1, bk, D),
                    lambda b, h, t, r, c, f: (b, h // G, c[t], 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, t, r, c, f: (b, h, r[t], 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((bq, D), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(lasts), q, k, v)
    return out


def _flash_ragged_kernel(
    rows_ref,  # scalar prefetch: (T,) int32
    cols_ref,  # scalar prefetch: (T,) int32
    lasts_ref,  # scalar prefetch: (T,) int32
    lens_ref,  # scalar prefetch: (B,) int32 — true length per row
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bq, D)
    o_ref,  # (1, 1, bq, D)
    acc_ref,  # (bq, D) f32
    m_ref,  # (bq, 1) f32
    l_ref,  # (bq, 1) f32
    *,
    block_q: int,
    block_k: int,
):
    b = pl.program_id(0)
    t = pl.program_id(2)
    i = rows_ref[t]
    j = cols_ref[t]
    true_len = lens_ref[b]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)

    # Blocks fully beyond this row's true length are skipped: their DMA was
    # already suppressed by the clamped index map, and the update is guarded
    # here so the running stats are untouched.
    @pl.when((i * block_q < true_len) & (j * block_k < true_len))
    def _attend():
        _flash_body(
            q_ref[0, 0].astype(jnp.float32),
            k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32),
            # the q_idx bound fully masks padded query rows, so they emit
            # exact zeros rather than attending the row's live prefix
            (k_idx <= q_idx) & (k_idx < true_len) & (q_idx < true_len),
            k_idx.T < true_len,
            m_ref,
            l_ref,
            acc_ref,
        )

    @pl.when(lasts_ref[t] == 1)
    def _finalize():
        # padded rows never accumulate (l == 0) and come out exactly zero
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def flash_prefill_ragged(
    q: Array,  # (B, H, S, D) — S is the padded bucket length
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
    true_lens: Array,  # (B,) int32 — live tokens per row (may be 0)
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """Causal flash attention over power-of-two padded rows.

    Identical to ``flash_prefill`` on rows with ``true_lens[b] == S``; rows
    shorter than the bucket clamp their block index maps to the last live
    block (consecutive equal indices ⇒ no DMA) and skip the tail compute.
    Padded query positions produce exact zeros.
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    rows, cols, lasts = _tri_schedule(pl.cdiv(S, bq), pl.cdiv(S, bk), bq, bk)
    grid = (B, H, len(rows))

    def _q_map(b, h, t, r, c, f, ln):
        live = jnp.maximum((ln[b] + bq - 1) // bq, 1)
        return (b, h, jnp.minimum(r[t], live - 1), 0)

    def _kv_map(b, h, t, r, c, f, ln):
        live = jnp.maximum((ln[b] + bk - 1) // bk, 1)
        return (b, h // G, jnp.minimum(c[t], live - 1), 0)

    out = pl.pallas_call(
        functools.partial(_flash_ragged_kernel, block_q=bq, block_k=bk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bq, D), _q_map),
                pl.BlockSpec((1, 1, bk, D), _kv_map),
                pl.BlockSpec((1, 1, bk, D), _kv_map),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, bq, D), lambda b, h, t, r, c, f, ln: (b, h, r[t], 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((bq, D), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        interpret=interpret,
    )(
        jnp.asarray(rows),
        jnp.asarray(cols),
        jnp.asarray(lasts),
        true_lens.astype(jnp.int32),
        q,
        k,
        v,
    )
    return out
