"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sgmv_ref(
    x: Array,  # (B, S, d_in)
    lora_a: Array,  # (N, d_in, r)
    lora_b: Array,  # (N, r, d_out)
    adapter_ids: Array,  # (B,) int32
    scale: float = 1.0,
) -> Array:
    """Multi-LoRA delta: Δ[b] = (x[b] @ A[id[b]]) @ B[id[b]] · scale.

    A negative id marks a base-model row (shared-prefix span): Δ = 0."""
    ids = jnp.maximum(adapter_ids, 0)
    a = jnp.take(lora_a, ids, axis=0)
    b = jnp.take(lora_b, ids, axis=0)
    h = jnp.einsum("bsd,bdr->bsr", x.astype(jnp.float32), a.astype(jnp.float32))
    out = jnp.einsum("bsr,bro->bso", h, b.astype(jnp.float32)) * scale
    out = out * (adapter_ids >= 0).astype(out.dtype)[:, None, None]
    return out.astype(x.dtype)


def paged_attention_ref(
    q: Array,  # (B, H, D)
    k_pages: Array,  # (P, page_size, Hkv, D)
    v_pages: Array,  # (P, page_size, Hkv, D)
    block_tables: Array,  # (B, pages_per_seq) int32
    lengths: Array,  # (B,) int32 — tokens in each sequence
) -> Array:
    """Single-token decode attention over a paged KV pool."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    G = H // Hkv
    # gather pages: (B, pages_per_seq, page, Hkv, D) -> (B, T, Hkv, D)
    k = jnp.take(k_pages, block_tables, axis=0).reshape(B, pages_per_seq * page, Hkv, D)
    v = jnp.take(v_pages, block_tables, axis=0).reshape(B, pages_per_seq * page, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(D))
    T = pages_per_seq * page
    valid = jnp.arange(T)[None, :] < lengths[:, None]  # (B, T)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def flash_prefill_ref(
    q: Array,  # (B, H, S, D)
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
) -> Array:
    """Causal full-sequence attention (flash oracle)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum(
        "bkgsd,bktd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(D))
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(B, H, S, D).astype(q.dtype)
