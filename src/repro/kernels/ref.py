"""Pure-jnp oracles for every Pallas kernel (the correctness references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sgmv_ref(
    x: Array,  # (B, S, d_in)
    lora_a: Array,  # (N, d_in, r)
    lora_b: Array,  # (N, r, d_out)
    adapter_ids: Array,  # (B,) int32
    scale: float = 1.0,
) -> Array:
    """Multi-LoRA delta: Δ[b] = (x[b] @ A[id[b]]) @ B[id[b]] · scale.

    A negative id marks a base-model row (shared-prefix span): Δ = 0."""
    ids = jnp.maximum(adapter_ids, 0)
    a = jnp.take(lora_a, ids, axis=0)
    b = jnp.take(lora_b, ids, axis=0)
    h = jnp.einsum("bsd,bdr->bsr", x.astype(jnp.float32), a.astype(jnp.float32))
    out = jnp.einsum("bsr,bro->bso", h, b.astype(jnp.float32)) * scale
    out = out * (adapter_ids >= 0).astype(out.dtype)[:, None, None]
    return out.astype(x.dtype)


def paged_attention_ref(
    q: Array,  # (B, H, D)
    k_pages: Array,  # (P, page_size, Hkv, D)
    v_pages: Array,  # (P, page_size, Hkv, D)
    block_tables: Array,  # (B, pages_per_seq) int32
    lengths: Array,  # (B,) int32 — tokens in each sequence
) -> Array:
    """Single-token decode attention over a paged KV pool."""
    B, H, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    G = H // Hkv
    # gather pages: (B, pages_per_seq, page, Hkv, D) -> (B, T, Hkv, D)
    k = jnp.take(k_pages, block_tables, axis=0).reshape(B, pages_per_seq * page, Hkv, D)
    v = jnp.take(v_pages, block_tables, axis=0).reshape(B, pages_per_seq * page, Hkv, D)
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(D))
    T = pages_per_seq * page
    valid = jnp.arange(T)[None, :] < lengths[:, None]  # (B, T)
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    # a row with lengths == 0 has every logit at -1e30: softmax is uniform,
    # which would emit mean(V) — zero the masked weights so it emits zeros
    w = jnp.where(valid[:, None, None, :], w, 0.0)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def flash_prefill_ref(
    q: Array,  # (B, H, S, D)
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
) -> Array:
    """Causal full-sequence attention (flash oracle)."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum(
        "bkgsd,bktd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(D))
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    return out.reshape(B, H, S, D).astype(q.dtype)


def fused_sgmv_ref(
    x: Array,  # (B, S, d_in)
    w: Array,  # (d_in, d_out)
    lora_a: Array,  # (N, d_in, r)
    lora_b: Array,  # (N, r, d_out)
    adapter_ids: Array,  # (B,) int32 — negative marks a base-model row
    scale: float = 1.0,
) -> Array:
    """Fused base + LoRA projection: x·W + scale·(x·A[id])·B[id]."""
    base = jnp.einsum(
        "bsd,do->bso", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    delta = sgmv_ref(x, lora_a, lora_b, adapter_ids, scale=scale)
    return (base + delta.astype(jnp.float32)).astype(x.dtype)


def flash_prefill_ragged_ref(
    q: Array,  # (B, H, S, D)
    k: Array,  # (B, Hkv, S, D)
    v: Array,  # (B, Hkv, S, D)
    true_lens: Array,  # (B,) int32
) -> Array:
    """Causal attention over padded rows; padded query positions are zero."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, D)
    logits = jnp.einsum(
        "bkgsd,bktd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(D))
    pos = jnp.arange(S)
    valid = (pos[None, :, None] >= pos[None, None, :]) & (
        pos[None, None, :] < true_lens[:, None, None]
    )  # (B, S_q, S_k)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid[:, None, None], w, 0.0)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, v.astype(jnp.float32))
    out = out * (pos[None, :, None] < true_lens[:, None, None])[:, None, None]
    return out.reshape(B, H, S, D).astype(q.dtype)


def ragged_extend_ref(
    q: Array,  # (B, S, Hq, D)
    k: Array,  # (B, T, Hkv, D)
    v: Array,  # (B, T, Hkv, D)
    start: Array,  # (B,) int32
    true_lens: Array,  # (B,) int32
) -> Array:
    """Suffix attention against the cache; padded query positions are zero."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(D))
    q_pos = start[:, None] + jnp.arange(S)  # (B, S)
    k_pos = jnp.arange(T)
    valid = (k_pos[None, None, :] <= q_pos[:, :, None]) & (
        k_pos[None, None, :] < (start + true_lens)[:, None, None]
    )  # (B, S, T)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(valid[:, None, None], w, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    live = jnp.arange(S)[None, :] < true_lens[:, None]  # (B, S)
    out = out * live[:, :, None, None, None]
    return out.reshape(B, S, H, D).astype(q.dtype)
