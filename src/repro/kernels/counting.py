"""Analytic HBM-traffic and FLOP counters for the Pallas kernels.

Interpret mode cannot measure DMA, so the regression harness does not *time*
the bandwidth wins — it *counts* them, by replaying each kernel's exact grid
order and BlockSpec index maps in plain Python and tallying a block fetch
whenever the mapped block index differs from the previous grid step (Pallas
skips the copy when consecutive steps map to the same block — the mechanism
both the length-trimmed clamps and the revisit semantics rely on). The same
walk marks which steps execute compute (the ``pl.when`` guards), giving
analytic FLOPs. ``benchmarks/kernels_bench.py`` emits these counts per shape
and CI asserts the trimmed grids move strictly fewer bytes than their
rectangular/full-grid baselines; see README.md §Kernels.

Everything here is host-side integer arithmetic on static shapes — no jax.
"""

from __future__ import annotations

from typing import Sequence

from .flash_prefill import _tri_schedule


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


class _FetchCounter:
    """Counts block fetches: one per grid step whose index differs from the
    previous step's (consecutive equal indices ⇒ the DMA is skipped)."""

    def __init__(self) -> None:
        self.fetches = 0
        self._prev: object = None

    def visit(self, index: object) -> None:
        if index != self._prev:
            self.fetches += 1
            self._prev = index

    def reset(self) -> None:
        """Forget the resident block (kernel boundary: VMEM does not
        persist across launches)."""
        self._prev = None


def flash_prefill_counts(
    B: int,
    H: int,
    Hkv: int,
    S: int,
    D: int,
    *,
    block_q: int = 128,
    block_k: int = 128,
    itemsize: int = 4,
    true_lens: Sequence[int] | None = None,
    variant: str = "block_skip",
) -> dict:
    """Counted traffic for ``flash_prefill`` / ``flash_prefill_ragged``.

    ``variant="rect"`` replays the historical rectangular grid (above-diagonal
    blocks fetched, compute ``pl.when``-skipped) as the baseline;
    ``"block_skip"`` replays the triangular flattened schedule. Passing
    ``true_lens`` replays the ragged index-map clamps on top.
    """
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, S)
    qb, kb = _cdiv(S, bq), _cdiv(S, bk)
    if variant == "block_skip":
        rows, cols, _ = _tri_schedule(qb, kb, bq, bk)
        sched = list(zip(rows.tolist(), cols.tolist()))
    elif variant == "rect":
        sched = [(i, j) for i in range(qb) for j in range(kb)]
    else:
        raise ValueError(f"unknown variant {variant!r}")
    lens = list(true_lens) if true_lens is not None else [S] * B
    kv_ctr, q_ctr = _FetchCounter(), _FetchCounter()
    flops = 0
    for b in range(B):
        live_q = max(_cdiv(lens[b], bq), 1)
        live_k = max(_cdiv(lens[b], bk), 1)
        for h in range(H):
            for i, j in sched:
                i_eff = min(i, live_q - 1) if true_lens is not None else i
                j_eff = min(j, live_k - 1) if true_lens is not None else j
                q_ctr.visit((b, h, i_eff))
                kv_ctr.visit((b, h // G, j_eff))
                active = j * bk <= i * bq + bq - 1  # causal intersection
                if true_lens is not None:
                    active = active and i * bq < lens[b] and j * bk < lens[b]
                if active:
                    flops += 4 * bq * bk * D
    kv_bytes = kv_ctr.fetches * bk * D * itemsize * 2  # K and V
    q_bytes = q_ctr.fetches * bq * D * itemsize
    return {
        "grid_steps": B * H * len(sched),
        "kv_block_fetches": kv_ctr.fetches,
        "kv_bytes": kv_bytes,
        "q_bytes": q_bytes,
        "hbm_bytes": kv_bytes + 2 * q_bytes,  # q in, o out
        "flops": flops,
    }


def paged_attention_counts(
    B: int,
    H: int,
    Hkv: int,
    D: int,
    page_size: int,
    pages_per_seq: int,
    lengths: Sequence[int],
    *,
    itemsize: int = 4,
    trimmed: bool = True,
) -> dict:
    """Counted traffic for ``paged_attention``.

    ``trimmed=False`` replays the historical full-grid fetch (every page of
    every sequence streamed, tokens masked after the fact).
    """
    G = H // Hkv
    kv_ctr = _FetchCounter()
    flops = 0
    for b in range(B):
        live = max(_cdiv(lengths[b], page_size), 1)
        for h in range(Hkv):
            for p in range(pages_per_seq):
                p_eff = min(p, live - 1) if trimmed else p
                kv_ctr.visit((b, h, p_eff))
                if not trimmed or p * page_size < lengths[b]:
                    flops += 4 * G * page_size * D
    kv_bytes = kv_ctr.fetches * page_size * D * itemsize * 2
    q_bytes = B * H * D * itemsize
    return {
        "grid_steps": B * Hkv * pages_per_seq,
        "kv_block_fetches": kv_ctr.fetches,
        "kv_bytes": kv_bytes,
        "q_bytes": q_bytes,
        "hbm_bytes": kv_bytes + 2 * q_bytes,
        "flops": flops,
    }


def ragged_extend_counts(
    B: int,
    H: int,
    Hkv: int,
    S: int,
    T: int,
    D: int,
    start: Sequence[int],
    true_lens: Sequence[int],
    *,
    block_q: int = 128,
    block_k: int = 128,
    itemsize: int = 4,
    trimmed: bool = True,
) -> dict:
    """Counted traffic for ``ragged_extend``.

    ``trimmed=False`` replays the dense baseline (every q block attends every
    cache block of the padded rectangle, masking after the fetch) — what the
    jnp ``sdpa`` path pays.
    """
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    qb, kb = _cdiv(S, bq), _cdiv(T, bk)
    kv_ctr, q_ctr = _FetchCounter(), _FetchCounter()
    flops = 0
    for b in range(B):
        live_q = max(_cdiv(true_lens[b], bq), 1)
        frontier = max(_cdiv(start[b] + true_lens[b], bk), 1)
        for h in range(H):
            for i in range(qb):
                i_eff = min(i, live_q - 1) if trimmed else i
                diag = (start[b] + i_eff * bq + bq - 1) // bk + 1
                live_k = max(min(frontier, diag), 1)
                for j in range(kb):
                    j_eff = min(j, live_k - 1) if trimmed else j
                    q_ctr.visit((b, h, i_eff))
                    kv_ctr.visit((b, h // G, j_eff))
                    active = (
                        i * bq < true_lens[b]
                        and j * bk < start[b] + true_lens[b]
                        and j * bk <= start[b] + i * bq + bq - 1
                    )
                    if not trimmed or active:
                        flops += 4 * bq * bk * D
    kv_bytes = kv_ctr.fetches * bk * D * itemsize * 2
    q_bytes = q_ctr.fetches * bq * D * itemsize
    return {
        "grid_steps": B * H * qb * kb,
        "kv_block_fetches": kv_ctr.fetches,
        "kv_bytes": kv_bytes,
        "q_bytes": q_bytes,
        "hbm_bytes": kv_bytes + 2 * q_bytes,
        "flops": flops,
    }


def sgmv_counts(
    B: int,
    S: int,
    d_in: int,
    d_out: int,
    r: int,
    *,
    block_s: int = 128,
    block_o: int = 128,
    itemsize: int = 4,
    fused: bool = True,
) -> dict:
    """Counted activation traffic for the LoRA projection.

    ``fused=True`` replays ``fused_sgmv`` (one kernel: the x tile is fetched
    once per token tile and read once per (token, out) block);
    ``fused=False`` replays the unfused pair — base matmul kernel plus the
    shrink/expand ``sgmv`` kernel — each streaming the x tile again.
    """
    bs = min(block_s, S)
    bo = min(block_o, d_out)
    sb, ob = _cdiv(S, bs), _cdiv(d_out, bo)
    kernels = 1 if fused else 2  # fused vs (base matmul, sgmv)
    x_ctr = _FetchCounter()
    for _ in range(kernels):
        x_ctr.reset()
        for b in range(B):
            for s in range(sb):
                for o in range(ob):
                    x_ctr.visit((b, s))
    x_bytes = x_ctr.fetches * bs * d_in * itemsize
    flops = 2 * B * S * d_in * d_out + 2 * B * S * r * (d_in + d_out)
    return {
        "grid_steps": kernels * B * sb * ob,
        "x_tile_fetches": x_ctr.fetches,
        "x_passes_per_block": x_ctr.fetches / (B * sb),
        "x_bytes": x_bytes,
        "kernel_launches": kernels,
        "flops": flops,
    }
