"""Pallas TPU kernels for the serving hot-spots (validated in interpret mode
on CPU, compiled via Mosaic on TPU):

* ``sgmv``                 — multi-LoRA batched matmul (adapter gather in the
                             BlockSpec index map; Punica/S-LoRA's SGMV)
* ``fused_sgmv``           — base projection + LoRA delta in one kernel: one
                             pass over each activation tile
* ``paged_attention``      — decode attention over the paged KV pool, page
                             index map length-trimmed via scalar prefetch
* ``flash_prefill``        — causal flash attention on a block-skip
                             (triangular flattened) grid
* ``flash_prefill_ragged`` — same, with per-row true lengths trimming the
                             padded bucket tail
* ``ragged_extend``        — suffix-chunk attention against the dense KV
                             cache (the engine's one-true-step kernel)

Design notes and the counted-bytes methodology live in README.md §Kernels;
``counting`` holds the analytic DMA/FLOP counters the regression harness
asserts against.
"""

from . import counting, ref
from .ops import (
    flash_prefill,
    flash_prefill_ragged,
    fused_sgmv,
    paged_attention,
    ragged_extend,
    sgmv,
)

__all__ = [
    "flash_prefill",
    "flash_prefill_ragged",
    "fused_sgmv",
    "paged_attention",
    "ragged_extend",
    "sgmv",
    "ref",
    "counting",
]
