"""Pallas TPU kernels for the serving hot-spots (validated in interpret mode
on CPU, compiled via Mosaic on TPU):

* ``sgmv``            — multi-LoRA batched matmul (adapter gather in the
                        BlockSpec index map; Punica/S-LoRA's SGMV, TPU-native)
* ``paged_attention`` — decode attention over the paged KV pool (block-table
                        indirection via scalar prefetch)
* ``flash_prefill``   — causal flash attention for prefill
"""

from . import ref
from .ops import flash_prefill, paged_attention, sgmv

__all__ = ["flash_prefill", "paged_attention", "sgmv", "ref"]
