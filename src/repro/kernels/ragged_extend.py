"""Ragged extend attention — the serving hot loop's kernel.

The engine's one-true-step (`models.attention.gqa_cached`) attends a batch of
fresh suffix chunks against the dense KV cache: row ``b`` holds
``true_lens[b]`` live query tokens (mixed batches pack decode riders with
``true_lens == 1`` next to prefill chunks; bucket padding brings every row to
the same ``S``) whose absolute positions start at ``start[b]``, and the keys
are cache positions ``0 .. start[b] + true_lens[b] - 1``. This kernel computes
exactly that — causal flash attention with a per-row key frontier — directly
on the engine's native layouts (``q (B, S, Hq, D)``, cache ``(B, T, Hkv, D)``,
no transposes).

Trimming (README.md §Kernels): ``start`` and ``true_lens`` are scalar-
prefetched. KV blocks past a row's frontier — beyond
``ceil((start+true_lens)/block_k)`` or above the causal diagonal of its
query block — clamp their index map to the last live block, and Pallas skips
the DMA when consecutive grid steps map to the same block; query blocks past
``ceil(true_lens/block_q)`` clamp the same way. Compute for trimmed blocks is
``pl.when``-guarded, so a decode rider in a padded bucket costs one q block ×
its live KV prefix, not ``S/bq × T/bk`` rectangles. Rows with
``true_lens[b] == 0`` (inactive slots) emit exact zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _ragged_extend_kernel(
    start_ref,  # scalar prefetch: (B,) int32 — first absolute q position
    lens_ref,  # scalar prefetch: (B,) int32 — live q tokens per row
    q_ref,  # (1, bq, 1, D)
    k_ref,  # (1, bk, 1, D)
    v_ref,  # (1, bk, 1, D)
    o_ref,  # (1, bq, 1, D)
    acc_ref,  # (bq, D) f32
    m_ref,  # (bq, 1) f32
    l_ref,  # (bq, 1) f32
    *,
    block_q: int,
    block_k: int,
    kv_blocks: int,
):
    b = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)
    start = start_ref[b]
    n_new = lens_ref[b]
    limit = start + n_new  # first invalid absolute key position

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip: padded q blocks, kv blocks past the row frontier, and kv blocks
    # fully above this q block's causal diagonal
    active = (
        (i * block_q < n_new)
        & (j * block_k < limit)
        & (j * block_k <= start + i * block_q + block_q - 1)
    )

    @pl.when(active)
    def _attend():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        D = q.shape[-1]
        q_pos = (
            start
            + i * block_q
            + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
        )
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
            jnp.float32(D)
        )
        # the q_pos < limit bound fully masks padded query rows, so they
        # emit exact zeros rather than attending the row's live prefix
        mask = (k_pos <= q_pos) & (k_pos < limit) & (q_pos < limit)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        # zero masked probabilities so fully-masked rows keep l == 0
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        # zero V rows past the frontier: when T is not a block multiple the
        # out-of-bounds tail reads back garbage and 0·garbage is not 0
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, jnp.where(k_pos.T < limit, v, 0.0),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_cur

    @pl.when(j == kv_blocks - 1)
    def _finalize():
        # rows that never accumulated (padding / inactive) come out zero
        o_ref[0, :, 0, :] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def ragged_extend(
    q: Array,  # (B, S, Hq, D) — padded suffix chunks
    k: Array,  # (B, T, Hkv, D) — dense KV cache (new rows already written)
    v: Array,  # (B, T, Hkv, D)
    start: Array,  # (B,) int32 — cache length before this chunk
    true_lens: Array,  # (B,) int32 — live tokens in each row (may be 0)
    *,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> Array:
    """Causal suffix attention against the cache with per-row trimming.

    Row ``b``'s query token ``s`` (for ``s < true_lens[b]``) attends cache
    positions ``0 .. start[b] + s``. Padded query positions — including whole
    rows with ``true_lens[b] == 0`` — return exact zeros.
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    kv_blocks = pl.cdiv(T, bk)
    grid = (B, H, pl.cdiv(S, bq), kv_blocks)

    def _q_map(b, h, i, j, st, ln):
        live = jnp.maximum((ln[b] + bq - 1) // bq, 1)
        return (b, jnp.minimum(i, live - 1), h, 0)

    def _kv_map(b, h, i, j, st, ln):
        q_live = jnp.maximum((ln[b] + bq - 1) // bq, 1)
        i_eff = jnp.minimum(i, q_live - 1)
        # last block any query of this row may see: min(row frontier,
        # this q block's causal diagonal)
        frontier = jnp.maximum((st[b] + ln[b] + bk - 1) // bk, 1)
        diag = (st[b] + i_eff * bq + bq - 1) // bk + 1
        live = jnp.maximum(jnp.minimum(frontier, diag), 1)
        return (b, jnp.minimum(j, live - 1), h // G, 0)

    out = pl.pallas_call(
        functools.partial(
            _ragged_extend_kernel, block_q=bq, block_k=bk, kv_blocks=kv_blocks
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bq, 1, D), _q_map),
                pl.BlockSpec((1, bk, 1, D), _kv_map),
                pl.BlockSpec((1, bk, 1, D), _kv_map),
            ],
            out_specs=pl.BlockSpec(
                (1, bq, 1, D), lambda b, h, i, j, st, ln: (b, i, h, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((bq, D), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
                pltpu.VMEM((bq, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, D), q.dtype),
        interpret=interpret,
    )(start.astype(jnp.int32), true_lens.astype(jnp.int32), q, k, v)
    return out
