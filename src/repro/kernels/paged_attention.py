"""Paged decode attention as a Pallas TPU kernel.

vLLM's CUDA paged attention gathers KV pages with per-warp loads. The TPU
adaptation (README.md §Kernels) keeps the KV pool as dense
``(num_pages, page_size, Hkv, D)`` arrays in HBM and streams one page per
grid step into VMEM, with the page indirection performed by the **scalar-
prefetched block table inside the BlockSpec index map** — the TPU-idiomatic
replacement for pointer-chasing. Softmax is computed online (flash-style
running max / sum in VMEM scratch) across the page-grid dimension, which is
sequential on TPU, so the accumulator carries across pages of one sequence.

Length trimming: the prefetched ``lens`` clamp the page index map to each
sequence's last live page — Pallas skips the DMA when consecutive grid steps
map to the same block, so pages past ``ceil(lens[b]/page_size)`` cost no
bandwidth — and the accumulate is ``pl.when``-guarded on the same bound so
they cost no MXU work either. Rows with ``lens[b] == 0`` (inactive slots in
a row-masked mixed batch) produce exact zeros: masked probabilities are
zeroed before they reach the accumulator, so ``l`` stays 0 and the finalize
guard divides 0/1, not the historical ``exp(NEG_INF - NEG_INF) = 1`` path
that silently emitted ``mean(V)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _paged_attn_kernel(
    tables_ref,  # scalar prefetch: (B, pages_per_seq) int32
    lens_ref,  # scalar prefetch: (B,) int32
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, page, 1, D)
    v_ref,  # (1, page, 1, D)
    o_ref,  # (1, 1, G, D)
    acc_ref,  # VMEM scratch (G, D) f32
    m_ref,  # VMEM scratch (G, 1) f32
    l_ref,  # VMEM scratch (G, 1) f32
    *,
    page_size: int,
    pages_per_seq: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Pages at or past the sequence length are skipped outright (their DMA
    # was already suppressed by the clamped index map below).
    @pl.when(p * page_size < lens_ref[b])
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (page, D)
        D = q.shape[-1]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
            jnp.float32(D)
        )  # (G, page)
        # mask tokens beyond the sequence length
        token_idx = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1
        )
        valid = token_idx < lens_ref[b]
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]  # (G, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p_ij = jnp.exp(s - m_cur)  # (G, page)
        # zero masked probabilities explicitly: when every score in the page
        # is NEG_INF, exp(s - m) is 1, not 0 — without this, a row whose
        # length is 0 averages V instead of emitting zeros
        p_ij = jnp.where(valid, p_ij, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p_ij, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p_ij, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: Array,  # (B, H, D)
    k_pages: Array,  # (P, page_size, Hkv, D)
    v_pages: Array,  # (P, page_size, Hkv, D)
    block_tables: Array,  # (B, pages_per_seq) int32
    lengths: Array,  # (B,) int32
    *,
    interpret: bool = False,
) -> Array:
    B, H, D = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    pages_per_seq = block_tables.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, pages_per_seq)

    def _kv_map(b, h, p, t, l):
        # clamp to the last live page: steps past it revisit the same block,
        # and a revisited block is not re-fetched
        live = jnp.maximum((l[b] + page_size - 1) // page_size, 1)
        return (t[b, jnp.minimum(p, live - 1)], 0, h, 0)

    out = pl.pallas_call(
        functools.partial(
            _paged_attn_kernel, page_size=page_size, pages_per_seq=pages_per_seq
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, p, t, l: (b, h, 0, 0)),
                pl.BlockSpec((1, page_size, 1, D), _kv_map),
                pl.BlockSpec((1, page_size, 1, D), _kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, p, t, l: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, D), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, D)
