"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic. ``interpret`` defaults to auto-detection.
"""

from __future__ import annotations

import jax

from . import ref
from .flash_prefill import flash_prefill as _flash
from .flash_prefill import flash_prefill_ragged as _flash_ragged
from .paged_attention import paged_attention as _paged
from .ragged_extend import ragged_extend as _ragged_extend
from .sgmv import fused_sgmv as _fused_sgmv
from .sgmv import sgmv as _sgmv


def _auto_interpret() -> bool:
    return jax.default_backend() == "cpu"


def sgmv(x, lora_a, lora_b, adapter_ids, *, scale: float = 1.0,
         block_s: int = 128, block_o: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _sgmv(x, lora_a, lora_b, adapter_ids, scale=scale,
                 block_s=block_s, block_o=block_o, interpret=interpret)


def fused_sgmv(x, w, lora_a, lora_b, adapter_ids, *, scale: float = 1.0,
               block_s: int = 128, block_o: int = 128,
               interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _fused_sgmv(x, w, lora_a, lora_b, adapter_ids, scale=scale,
                       block_s=block_s, block_o=block_o, interpret=interpret)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _paged(q, k_pages, v_pages, block_tables, lengths, interpret=interpret)


def flash_prefill(q, k, v, *, block_q: int = 128, block_k: int = 128,
                  interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _flash(q, k, v, block_q=block_q, block_k=block_k, interpret=interpret)


def flash_prefill_ragged(q, k, v, true_lens, *, block_q: int = 128,
                         block_k: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _flash_ragged(q, k, v, true_lens, block_q=block_q, block_k=block_k,
                         interpret=interpret)


def ragged_extend(q, k, v, start, true_lens, *, block_q: int = 128,
                  block_k: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _auto_interpret()
    return _ragged_extend(q, k, v, start, true_lens, block_q=block_q,
                          block_k=block_k, interpret=interpret)


__all__ = [
    "sgmv",
    "fused_sgmv",
    "paged_attention",
    "flash_prefill",
    "flash_prefill_ragged",
    "ragged_extend",
    "ref",
]
