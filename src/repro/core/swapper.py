"""Performance-driven cache swapper (FASTLIBRA §5.3).

Every monitor interval (100 ms) the swapper reads HBM usage from the cache
manager:

* usage > upper threshold (95 %) ⇒ **busy**: swap out HBM-leaf candidates in
  *ascending* Eval order until usage drops back under the upper threshold;
* usage < lower threshold (70 %) ⇒ **idle**: prefetch host-root candidates in
  *descending* Eval order until usage reaches the lower threshold (this is
  what proactively loads all LoRAs at t≈0 in the paper's Fig. 14a).

The two-threshold hysteresis prevents ping-pong swapping. Candidates are
refreshed after every move because evicting a leaf exposes its parent and
swapping in a root exposes its children.

Straggler mitigation (beyond-paper, §DESIGN 5): if the caller reports that a
previously-issued transfer has exceeded ``straggler_timeout``, the swapper
re-issues it (hedged swap) — the manager's block accounting is idempotent for
re-issues because the node already sits in its destination tier.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..obs import EV_CACHE_EVICT, EV_CACHE_PREFETCH
from .cache_manager import CacheManager, SwapOp, _audit_kind
from .dependency_tree import NodeKind


@dataclasses.dataclass
class SwapperConfig:
    monitor_interval: float = 0.1  # seconds (paper: 100 ms)
    upper_threshold: float = 0.95
    lower_threshold: float = 0.70
    max_moves_per_tick: int = 512  # safety valve
    straggler_timeout: float = 1.0
    enabled: bool = True  # baselines run demand-paging only


class CacheSwapper:
    def __init__(self, manager: CacheManager, config: Optional[SwapperConfig] = None):
        self.manager = manager
        self.config = config or SwapperConfig()
        self.last_tick = 0.0
        self._recent_batch_size = 0.0
        self.ticks = 0
        self.total_ops = 0

    def observe_batch_size(self, bs: float) -> None:
        """Engine reports the recent (last 5 s) average batch load (§5.1).

        With the mixed step scheduler this is the UNIFIED mixed-batch token
        count per step — decode rows contribute 1 token, prefill rows their
        chunk slice — one signal instead of a decode-slot count that was
        blind to the prefill share of each batch."""
        self._recent_batch_size = bs
        obs = getattr(self.manager.scorer, "observe_batch_size", None)
        if obs:
            obs(bs)

    def due(self, now: float) -> bool:
        return self.config.enabled and (
            now - self.last_tick >= self.config.monitor_interval
        )

    def tick(self, now: float) -> list[SwapOp]:
        """One monitor-interval sweep; returns the executed swap plan."""
        self.last_tick = now
        self.ticks += 1
        if not self.config.enabled:
            return []
        mgr = self.manager
        cfg = self.config
        mgr.scorer.refresh(now)
        ops: list[SwapOp] = []
        usage = mgr.hbm_usage()
        if usage > cfg.upper_threshold:
            ops.extend(self._swap_out_sweep(now))
        elif usage < cfg.lower_threshold:
            ops.extend(self._swap_in_sweep(now))
        self.total_ops += len(ops)
        mgr.sanitize_check("swapper.tick")
        return ops

    # ------------------------------------------------------------------ busy
    def _swap_out_sweep(self, now: float) -> list[SwapOp]:
        mgr, cfg = self.manager, self.config
        ops: list[SwapOp] = []
        while (
            mgr.hbm_usage() > cfg.upper_threshold
            and len(ops) < cfg.max_moves_per_tick
        ):
            cands = mgr.evict_candidates()
            if not cands:
                break
            # node_id tiebreak keeps victim choice deterministic on equal Eval
            victim = min(cands, key=lambda n: (mgr.scorer.score(n, now), n.node_id))
            if mgr.tracer.enabled:
                # audit the proactive-pressure decision: victim score + the
                # surviving candidates it beat (lowest-scored first)
                ranked = sorted(
                    ((mgr.scorer.score(n, now), n.node_id) for n in cands
                     if n is not victim))
                mgr.tracer.audit(
                    EV_CACHE_EVICT, now, node_id=victim.node_id,
                    kind=_audit_kind(victim), lora=victim.lora_id,
                    bytes=victim.size_bytes,
                    score=mgr.scorer.score(victim, now), reason="pressure",
                    beat=[[nid, sc] for sc, nid in ranked[:3]])
            ops.append(mgr._swap_out_node(victim, now))
        return ops

    # ------------------------------------------------------------------ idle
    def _swap_in_sweep(self, now: float) -> list[SwapOp]:
        mgr, cfg = self.manager, self.config
        ops: list[SwapOp] = []
        while (
            mgr.hbm_usage() < cfg.lower_threshold
            and len(ops) < cfg.max_moves_per_tick
        ):
            if mgr.config.maintain_dependencies:
                cands = mgr.tree.host_roots()
            else:
                cands = [
                    n
                    for n in mgr.tree.iter_nodes()
                    if n.tier is not None and n.tier.value == "host"
                ]
            if not cands:
                break
            # -node_id tiebreak: on equal Eval prefetch the oldest node
            # deterministically instead of whatever dict order yields first
            best = max(cands, key=lambda n: (mgr.scorer.score(n, now), -n.node_id))
            # prefetch only while it fits without evicting anything hotter
            pool = mgr._pool_for(best.kind)
            from .block_pool import Tier

            if not pool.can_allocate(Tier.HBM, best.num_blocks):
                break
            op = mgr._swap_in_node(best, now)
            if op is None:
                break
            if mgr.tracer.enabled:
                # idle-prefetch decision: chosen node + the runners-up it
                # outscored (highest-scored first)
                ranked = sorted(
                    ((mgr.scorer.score(n, now), n.node_id) for n in cands
                     if n is not best), reverse=True)
                mgr.tracer.audit(
                    EV_CACHE_PREFETCH, now, node_id=best.node_id,
                    kind=_audit_kind(best), lora=best.lora_id,
                    bytes=best.size_bytes,
                    score=mgr.scorer.score(best, now),
                    beat=[[nid, sc] for sc, nid in ranked[:3]])
            ops.append(op)
        return ops


def make_fastlibra(
    hbm_bytes: int,
    host_bytes: int,
    *,
    kv_bytes_per_token: int,
    block_size: int = 32,
    hardware=None,
    variant: str = "fastlibra",
    state_bytes: int = 0,
    sanitize: Optional[bool] = None,
    share_prefix_kv: bool = True,
    tracer=None,
) -> tuple[CacheManager, CacheSwapper]:
    """Factory for FASTLIBRA and every paper baseline/ablation.

    variants: fastlibra | fastlibra-paper | wom | wos | wol | vllm | slora
    (fastlibra-paper = literal Eq.6 ordering without the density correction)

    ``state_bytes > 0`` (recurrent archs) makes the prefix layer state
    snapshots instead of per-token KV — every variant keeps its own
    eviction/partitioning semantics over the snapshot nodes, and the
    proactive swapper moves whole snapshots through the same SwapOp plan.

    ``share_prefix_kv=False`` disables the cross-adapter shared trunk:
    declared shared spans are still base-computed but cached per adapter —
    the differential baseline for the sharing refactor.

    ``tracer`` attaches a :class:`repro.obs.Tracer` so every manager and
    swapper cache decision lands in the audit log (default: no-op tracer).
    """
    from .cache_manager import ManagerConfig

    base = dict(block_size=block_size, kv_bytes_per_token=kv_bytes_per_token,
                state_bytes=state_bytes, sanitize=sanitize,
                share_prefix_kv=share_prefix_kv)
    sw = SwapperConfig()
    if variant == "fastlibra":
        cfg = ManagerConfig(**base)
    elif variant == "fastlibra-paper":
        cfg = ManagerConfig(**base, density_ordering=False)
    elif variant == "wom":  # no dependency maintenance
        cfg = ManagerConfig(**base, maintain_dependencies=False)
    elif variant == "wos":  # LRU instead of the cost model
        cfg = ManagerConfig(**base, use_cost_model=False)
    elif variant == "wol":  # no LoRA-quantity reward (Eq. 4 dropped)
        cfg = ManagerConfig(**base, lora_reward=False)
    elif variant == "vllm":  # static partition + LRU + prefix caching
        cfg = ManagerConfig(
            **base,
            maintain_dependencies=False,
            unified_pool=False,
            use_cost_model=False,
        )
        sw = SwapperConfig(enabled=False)  # demand paging only
    elif variant == "slora":  # unified pool, no history-KV reuse
        cfg = ManagerConfig(
            **base,
            maintain_dependencies=True,
            reuse_history_kv=False,
            use_cost_model=False,
        )
        sw = SwapperConfig(enabled=False)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    mgr = CacheManager(cfg, hbm_bytes, host_bytes, hardware=hardware,
                       tracer=tracer)
    return mgr, CacheSwapper(mgr, sw)
