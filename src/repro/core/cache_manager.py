"""Dependency-aware cache manager (FASTLIBRA §4) + baseline variants.

One code path, parametrized the way the paper's ablations are:

* ``maintain_dependencies`` — True: swap-out only dependency-tree leaves /
  swap-in only host roots (validity invariant holds ⇒ zero invalid KVs).
  False (FASTLIBRA-WOM, vLLM): any unpinned HBM node may be evicted
  independently, so a LoRA can leave while its KV subtree stays (invalid KVs).
* ``unified_pool`` — True: one block pool shared by LoRAs + KVs (FASTLIBRA,
  S-LoRA). False (vLLM): static partition, ``lora_partition_ratio`` of HBM
  blocks reserved for LoRAs, the rest for KVs; the two regions cannot borrow.
* ``reuse_history_kv`` — False (S-LoRA): KV blocks are freed at query end and
  never enter the tree.
* ``scorer`` — CostModelScorer (Eq. 6) or LRUScorer; ``lora_reward=False``
  gives FASTLIBRA-WOL.
* ``state_bytes`` — > 0 turns the prefix layer into recurrent-state snapshot
  nodes (RWKV/RG-LRU): ``lookup_state`` resumes from the deepest snapshot at
  or below the prompt and ``commit_state`` captures new boundaries; the same
  unified pool, dependency/validity machinery and swapper move whole
  snapshots instead of per-token blocks.

The manager is pure control plane and time-explicit (``now`` is passed in),
so the discrete-event simulator and the real JAX engine drive the *same*
object. All pool mutations are returned as :class:`SwapOp` records for the
data plane (physical copies) or the simulator (PCIe timing).
"""

from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Optional, Sequence

from .block_pool import BlockPool, PoolExhausted, Tier
from .cost_model import (
    CostModelScorer,
    HardwareModel,
    LRUScorer,
    admission_ttft_estimate,
)
from .dependency_tree import (
    DependencyTree,
    MatchResult,
    Node,
    NodeKind,
    Residency,
)
from .invariants import (
    PoolInvariantError,
    check_pool_invariants,
    sanitize_enabled,
)
from ..obs import (
    EV_CACHE_ADMIT,
    EV_CACHE_COMMIT,
    EV_CACHE_DROP,
    EV_CACHE_EVICT,
    EV_CACHE_LOAD,
    EV_CACHE_PREEMPT,
    EV_CACHE_SWAP_IN,
    EV_CACHE_SWAP_OUT,
    NULL_TRACER,
)


def _audit_kind(node: "Node") -> str:
    """Audit-log kind label: LoRA / KV / STATE, with the base-model trunk
    (adapter-independent KV, ``lora_id=None``) called out as shared-trunk."""
    if node.kind is NodeKind.KV and getattr(node, "is_shared", False):
        return "shared-trunk"
    return node.kind.value


def _checked(fn):
    """Run the full pool-invariant sweep after a mutating public op when the
    sanitizer is on (``REPRO_SANITIZE=1`` or ``ManagerConfig(sanitize=True)``).
    Corruption is then caught at the op that introduced it, not at whatever
    later op trips over it."""

    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        out = fn(self, *args, **kwargs)
        if self._sanitize:
            check_pool_invariants(self, context=fn.__name__)
        return out

    return wrapper


class SwapKind(enum.Enum):
    SWAP_IN = "in"  # host -> HBM
    SWAP_OUT = "out"  # HBM -> host
    DROP = "drop"  # HBM -> gone (no host room) or host -> gone
    LOAD_NEW = "load"  # first-time LoRA registration into host


@dataclasses.dataclass
class SwapOp:
    kind: SwapKind
    node_kind: NodeKind
    lora_id: Optional[str]
    nbytes: int
    src_blocks: tuple[int, ...] = ()
    dst_blocks: tuple[int, ...] = ()
    node_id: int = -1

    @property
    def is_transfer(self) -> bool:
        return self.kind in (SwapKind.SWAP_IN, SwapKind.SWAP_OUT)


@dataclasses.dataclass
class LookupResult:
    match: MatchResult
    lora_resident: bool
    hbm_hit_tokens: int
    host_hit_tokens: int
    history_tokens: int  # reusable prefix length presented by the query
    swap_in_nodes: list[Node]  # host-resident nodes on the matched path
    # cross-adapter prefix sharing: the block-quantized adapter-independent
    # span the request declared (0 when sharing is off / undeclared) and how
    # many of those tokens the shared trunk served from HBM. ``commit`` uses
    # ``shared_prefix_len`` to classify the committed suffix into trunk
    # (lora_id=None) vs adapter-fork spans.
    shared_prefix_len: int = 0
    shared_hit_tokens: int = 0
    # recurrent-state lookups (lookup_state) only: the deepest snapshot node
    # carrying payload at or below the prompt, and the prefix boundary
    # (token count) decoding can resume from
    state_node: Optional[Node] = None
    state_tokens: int = 0


@dataclasses.dataclass
class AdmitResult:
    ops: list[SwapOp]
    pinned: list[Node]
    queued: bool = False  # True: not enough HBM even after eviction

    @property
    def swap_in_bytes(self) -> int:
        return sum(o.nbytes for o in self.ops if o.kind is SwapKind.SWAP_IN)


@dataclasses.dataclass
class ManagerConfig:
    block_size: int = 32  # tokens per KV block
    kv_bytes_per_token: int = 1 << 18  # arch-dependent; set by caller
    maintain_dependencies: bool = True
    unified_pool: bool = True
    lora_partition_ratio: float = 0.2
    reuse_history_kv: bool = True
    decay_tau: float = 60.0
    use_cost_model: bool = True
    lora_reward: bool = True
    sigmoid_tau: float = 15.0
    density_ordering: bool = True  # False = paper-literal Eval ordering
    # Recurrent-state prefix caching: > 0 enables STATE snapshot nodes of
    # this byte size (one full-model recurrent state). Snapshot boundaries
    # are arbitrary token positions — the data plane moves whole snapshots,
    # not per-token blocks — so the dependency tree runs unquantized
    # (align=1) when state caching is on.
    state_bytes: int = 0
    # Cross-adapter prefix sharing: requests may declare a leading
    # adapter-independent span (``shared_prefix_len`` — a system prompt
    # computed with the adapter inactive). Its KV is cached ONCE on a shared
    # base-model trunk under the tree root and forked per adapter below.
    # False keeps the declared span base-computed but caches it per adapter
    # (the differential baseline: identical tokens, duplicated cache).
    share_prefix_kv: bool = True
    # libra-check sanitizer: True/False forces the per-op invariant sweep on
    # or off; None defers to the REPRO_SANITIZE environment variable.
    sanitize: Optional[bool] = None

    @property
    def block_bytes(self) -> int:
        return self.block_size * self.kv_bytes_per_token

    @property
    def state_blocks(self) -> int:
        """Unified-pool blocks one state snapshot occupies."""
        return -(-self.state_bytes // self.block_bytes) if self.state_bytes else 0


@dataclasses.dataclass
class ManagerStats:
    lookups: int = 0
    lora_hbm_hits: int = 0
    kv_hbm_hit_tokens: int = 0
    kv_host_hit_tokens: int = 0
    history_tokens: int = 0
    swap_in_bytes: int = 0
    swap_out_bytes: int = 0
    swap_in_count: int = 0
    swap_out_count: int = 0
    drops: int = 0
    queue_events: int = 0
    # SLO-tier preemptions: victims whose running KV/state was folded into
    # the tree (demotable through the swapper) instead of discarded
    preemptions: int = 0
    # recurrent-state snapshot lookups (symmetric with the KV counters:
    # hit tokens are the prefix boundary a resumable snapshot covers)
    state_lookups: int = 0
    state_hits: int = 0
    state_hit_tokens: int = 0
    state_host_hit_tokens: int = 0
    # cross-adapter shared-prefix counters: declared (block-quantized)
    # adapter-independent tokens presented vs those the trunk served from HBM
    shared_history_tokens: int = 0
    shared_hbm_hit_tokens: int = 0

    def lora_hit_rate(self) -> float:
        return self.lora_hbm_hits / self.lookups if self.lookups else 0.0

    def kv_hit_rate(self) -> float:
        return (
            self.kv_hbm_hit_tokens / self.history_tokens
            if self.history_tokens
            else 0.0
        )

    def state_hit_rate(self) -> float:
        """Token-weighted snapshot hit rate (resumed / presented history)."""
        return (
            self.state_hit_tokens / self.history_tokens
            if self.history_tokens
            else 0.0
        )

    def shared_hit_rate(self) -> float:
        """Token-weighted HBM hit rate over declared shared-prefix spans."""
        return (
            self.shared_hbm_hit_tokens / self.shared_history_tokens
            if self.shared_history_tokens
            else 0.0
        )


class CacheManager:
    """Unified (or statically-partitioned) two-tier cache of LoRAs + KVs."""

    def __init__(
        self,
        config: ManagerConfig,
        hbm_bytes: int,
        host_bytes: int,
        hardware: Optional[HardwareModel] = None,
        tracer=None,
    ):
        self.config = config
        # cache-decision audit log (repro.obs): every admit/evict/swap is
        # recorded with node id, kind, bytes and cost-model score when a
        # real tracer is attached; the default is the no-op singleton.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._sanitize = (
            config.sanitize if config.sanitize is not None else sanitize_enabled()
        )
        self.hw = hardware or HardwareModel()
        bb = config.block_bytes
        n_hbm = max(1, hbm_bytes // bb)
        n_host = max(1, host_bytes // bb)
        # State snapshots live at arbitrary prefix boundaries (the data plane
        # moves whole fixed-size snapshots, never per-token blocks), so the
        # tree runs unquantized when state caching is enabled.
        align = 1 if config.state_bytes else config.block_size
        self.tree = DependencyTree(align=align, decay_tau=config.decay_tau,
                                   block_tokens=config.block_size)
        if config.unified_pool:
            self.pool = BlockPool(n_hbm, n_host, bb)
            self.lora_pool = self.pool
            self.kv_pool = self.pool
        else:
            n_lora = max(1, int(n_hbm * config.lora_partition_ratio))
            # host tier is always shared (paper: main memory is one arena)
            self.lora_pool = BlockPool(n_lora, n_host, bb)
            self.kv_pool = BlockPool(n_hbm - n_lora, 0, bb)
            self.kv_pool._free[Tier.HOST] = self.lora_pool._free[Tier.HOST]
            self.kv_pool._allocated[Tier.HOST] = self.lora_pool._allocated[Tier.HOST]
            self.kv_pool.num_host_blocks = n_host
            self.pool = self.kv_pool
        if config.use_cost_model:
            self.scorer: CostModelScorer | LRUScorer = CostModelScorer(
                self.tree,
                self.hw,
                lora_reward=config.lora_reward,
                sigmoid_tau=config.sigmoid_tau,
                density_ordering=config.density_ordering,
            )
        else:
            self.scorer = LRUScorer(self.tree)
        self.stats = ManagerStats()
        # per-query running KV blocks (not yet in the tree)
        self._running: dict[str, list[int]] = {}
        self._running_tokens: dict[str, int] = {}
        # queries preempted via preempt_running and not yet readmitted: the
        # sanitizer asserts they left no running-block residue; a fresh
        # allocate_running for the same id (the resume) clears the mark
        self._preempted: set[str] = set()
        # every swap op (incl. demand evictions inside admit/allocate) is
        # recorded here; the data plane / simulator drains and executes them.
        # Demand-eviction SWAP_OUTs are on the requesting query's critical
        # path (blocks are reusable only after the transfer) — the paper's
        # central cold-start mechanism that the proactive swapper avoids.
        self._pending_ops: list[SwapOp] = []

    # ------------------------------------------------------------ block math
    def kv_blocks_for(self, tokens: int) -> int:
        return -(-tokens // self.config.block_size)

    def _pool_for(self, kind: NodeKind) -> BlockPool:
        return self.lora_pool if kind is NodeKind.LORA else self.kv_pool

    def hbm_usage(self) -> float:
        if self.config.unified_pool:
            return self.pool.hbm_usage()
        used = (
            self.lora_pool.stats().hbm_used + self.kv_pool.stats().hbm_used
        )
        tot = self.lora_pool.num_hbm_blocks + self.kv_pool.num_hbm_blocks
        return used / tot

    # ---------------------------------------------------------------- LoRAs
    @_checked
    def register_lora(self, lora_id: str, size_bytes: int, now: float = 0.0) -> SwapOp:
        """Load a LoRA's weights into the host tier (from disk)."""
        nblocks = -(-size_bytes // self.config.block_bytes)
        blocks = self.lora_pool.allocate(Tier.HOST, nblocks)
        node = self.tree.add_lora(
            lora_id, size_bytes, nblocks, tier=Residency.HOST, now=now
        )
        node.host_blocks = blocks
        if self.tracer.enabled:
            self.tracer.audit(EV_CACHE_LOAD, now, node_id=node.node_id,
                              kind=_audit_kind(node), lora=lora_id,
                              bytes=size_bytes)
        return SwapOp(
            SwapKind.LOAD_NEW, NodeKind.LORA, lora_id, size_bytes,
            dst_blocks=tuple(blocks), node_id=node.node_id,
        )

    # ---------------------------------------------------------------- lookup
    @_checked
    def lookup(self, lora_id: str, history_tokens: Sequence[int], now: float,
               shared_prefix_len: int = 0) -> LookupResult:
        """Prefix lookup. ``shared_prefix_len`` declares how many leading
        history tokens are adapter-independent (computed with the adapter
        inactive): with ``share_prefix_kv`` on, that span is matched against
        the shared base-model trunk — hitting KV cached by *other* adapters —
        and committed there. The span is quantized down to the block size so
        trunk and fork edges stay block-aligned."""
        sq = 0
        if self.config.share_prefix_kv and shared_prefix_len > 0:
            bs = self.config.block_size
            sq = (min(shared_prefix_len, len(history_tokens)) // bs) * bs
        m = self.tree.match(lora_id, history_tokens, now, shared_len=sq)
        lora_resident = (
            m.lora_node is not None and m.lora_node.tier is Residency.HBM
        )
        swap_in: list[Node] = []
        if m.lora_node is not None and m.lora_node.tier is Residency.HOST:
            swap_in.append(m.lora_node)
        for n in m.kv_nodes:
            if n.tier is Residency.HOST:
                swap_in.append(n)
        res = LookupResult(
            match=m,
            lora_resident=lora_resident,
            hbm_hit_tokens=m.hbm_hit_tokens,
            host_hit_tokens=m.host_hit_tokens,
            history_tokens=len(history_tokens),
            swap_in_nodes=swap_in,
            shared_prefix_len=sq,
            shared_hit_tokens=m.shared_hbm_hit_tokens,
        )
        self.stats.lookups += 1
        self.stats.lora_hbm_hits += int(lora_resident)
        self.stats.kv_hbm_hit_tokens += m.hbm_hit_tokens
        self.stats.kv_host_hit_tokens += m.host_hit_tokens
        self.stats.history_tokens += len(history_tokens)
        self.stats.shared_history_tokens += sq
        self.stats.shared_hbm_hit_tokens += m.shared_hbm_hit_tokens
        return res

    @_checked
    def lookup_state(
        self, lora_id: str, history_tokens: Sequence[int], now: float
    ) -> LookupResult:
        """Recurrent-arch lookup: deepest *resumable* snapshot ≤ the prompt.

        The matched chain may contain hollow STATE interiors (radix-split
        residue carrying no snapshot) — only nodes with payload blocks are
        resume points, and a snapshot encodes the FULL prefix state at its
        boundary, so exactly one node (the deepest payload node) needs to be
        resident. ``swap_in_nodes`` still lists every host node on the path
        down to it, shallow→deep, so admit preserves the validity invariant
        (the hollow ones move zero bytes).
        """
        m = self.tree.match(lora_id, history_tokens, now)
        lora_resident = (
            m.lora_node is not None and m.lora_node.tier is Residency.HBM
        )
        snode: Optional[Node] = None
        stokens = 0
        pos = 0
        best_depth = 0
        for i, n in enumerate(m.kv_nodes):
            pos += n.num_tokens
            if n.kind is NodeKind.STATE and n.has_payload:
                snode, stokens, best_depth = n, pos, i + 1
        swap_in: list[Node] = []
        if m.lora_node is not None and m.lora_node.tier is Residency.HOST:
            swap_in.append(m.lora_node)
        for n in m.kv_nodes[:best_depth]:
            if n.tier is Residency.HOST:
                swap_in.append(n)
        hbm_hit = stokens if (snode is not None and snode.tier is Residency.HBM) else 0
        host_hit = stokens if (snode is not None and snode.tier is Residency.HOST) else 0
        res = LookupResult(
            match=m,
            lora_resident=lora_resident,
            hbm_hit_tokens=hbm_hit,
            host_hit_tokens=host_hit,
            history_tokens=len(history_tokens),
            swap_in_nodes=swap_in,
            state_node=snode,
            state_tokens=stokens,
        )
        self.stats.lookups += 1
        self.stats.lora_hbm_hits += int(lora_resident)
        self.stats.history_tokens += len(history_tokens)
        self.stats.state_lookups += 1
        self.stats.state_hits += int(snode is not None)
        self.stats.state_hit_tokens += hbm_hit
        self.stats.state_host_hit_tokens += host_hit
        return res

    # ----------------------------------------------------------------- admit
    @_checked
    def admit(self, lookup: LookupResult, now: float) -> AdmitResult:
        """Bring the query's LoRA + matched KV chain into HBM and pin them.

        Swap-ins allocate HBM blocks, evicting per the scorer on demand.
        Returns ``queued=True`` (and performs nothing) if HBM cannot hold the
        working set even after eviction — the caller re-tries later.
        """
        ops: list[SwapOp] = []
        needed = list(lookup.swap_in_nodes)
        # feasibility: everything needed must fit alongside pinned blocks
        for node in needed:
            pool = self._pool_for(node.kind)
            if node.num_blocks > pool.num_hbm_blocks:
                self.stats.queue_events += 1
                return AdmitResult(ops=[], pinned=[], queued=True)
        # Protect the query's whole working set while making room: without
        # this, swapping in a later node can evict an *earlier* node of the
        # same admission (e.g. the just-loaded LoRA to fit its own KV chain,
        # leaving an HBM child under a host parent — a validity violation the
        # state-interleave fuzz caught) or silently evict an already-resident
        # matched node whose blocks the data plane is about to gather.
        m = lookup.match
        protect = {n.node_id for n in needed}
        protect.update(n.node_id for n in m.kv_nodes)
        if m.lora_node is not None:
            protect.add(m.lora_node.node_id)
        # admit-shield integrity (sanitizer): every working-set node that is
        # HBM-resident when make-room starts must still be at admit end —
        # exactly the regression class the state-interleave fuzz caught.
        working_set = list(m.kv_nodes)
        if m.lora_node is not None:
            working_set.append(m.lora_node)
        shielded = (
            {n.node_id for n in working_set if n.tier is Residency.HBM}
            if self._sanitize
            else None
        )
        for node in needed:
            op = self._swap_in_node(node, now, protect=protect)
            if op is None:
                # roll back pins made so far; caller queues
                self.stats.queue_events += 1
                return AdmitResult(ops=[], pinned=[], queued=True)
            ops.append(op)
        # Pin the LoRA node and the *deepest* HBM-resident matched KV node
        # only: in dependency-maintained mode every ancestor is protected
        # structurally (it has an HBM child, so it is never an eviction
        # leaf), and pinning one node per path survives radix splits (the
        # original object always remains the deepest/lower half).
        pinned: list[Node] = []
        m = lookup.match
        if m.lora_node is not None and m.lora_node.tier is Residency.HBM:
            m.lora_node.ref_count += 1
            pinned.append(m.lora_node)
        deepest = next(
            (n for n in reversed(m.kv_nodes) if n.tier is Residency.HBM), None
        )
        if deepest is not None:
            deepest.ref_count += 1
            pinned.append(deepest)
        if shielded is not None:
            lost = [
                n for n in working_set + needed
                if n.node_id in shielded | {x.node_id for x in needed}
                and n.tier is not Residency.HBM
            ]
            if lost:
                raise PoolInvariantError(
                    "admit-shield: working-set node(s) evicted mid-admit: "
                    + ", ".join(
                        f"#{n.node_id}({n.kind.value}, tier={n.tier})"
                        for n in lost
                    ),
                )
        if self.tracer.enabled:
            self.tracer.audit(
                EV_CACHE_ADMIT, now,
                swapped_in=[n.node_id for n in needed],
                pinned=[n.node_id for n in pinned],
                hbm_hit_tokens=lookup.hbm_hit_tokens,
                host_hit_tokens=lookup.host_hit_tokens)
        return AdmitResult(ops=ops, pinned=pinned)

    @_checked
    def unpin(self, pinned: Sequence[Node]) -> None:
        for n in pinned:
            if n.ref_count > 0:
                n.ref_count -= 1

    # --------------------------------------------------------- running blocks
    @_checked
    def allocate_running(
        self, query_id: str, num_tokens: int, now: float
    ) -> Optional[list[int]]:
        """Allocate HBM blocks for a query's newly-computed KV (prefill suffix
        or decode growth). Returns None if HBM is exhausted even after
        eviction (query must queue / be preempted)."""
        nblocks = self.kv_blocks_for(num_tokens)
        # a resume allocation clears the preempted mark (the query is live
        # again and legitimately holds running blocks)
        self._preempted.discard(query_id)
        have = self._running.setdefault(query_id, [])
        cur_tokens = self._running_tokens.get(query_id, 0)
        need = self.kv_blocks_for(cur_tokens + num_tokens) - len(have)
        if need <= 0:
            self._running_tokens[query_id] = cur_tokens + num_tokens
            return []
        if not self._make_room(self.kv_pool, need, now):
            self.stats.queue_events += 1
            return None
        blocks = self.kv_pool.allocate(Tier.HBM, need)
        have.extend(blocks)
        self._running_tokens[query_id] = cur_tokens + num_tokens
        return blocks

    def running_blocks(self, query_id: str) -> list[int]:
        return list(self._running.get(query_id, ()))

    @_checked
    def abort_running(self, query_id: str) -> None:
        blocks = self._running.pop(query_id, [])
        self._running_tokens.pop(query_id, None)
        if blocks:
            self.kv_pool.release(Tier.HBM, blocks)

    @_checked
    def commit(
        self,
        query_id: str,
        lookup: LookupResult,
        full_tokens: Sequence[int],
        now: float,
    ) -> Optional[Node]:
        """Query finished: fold its running KV blocks into the tree.

        The matched prefix is already covered by tree nodes; the new suffix
        is classified at the request's declared ``shared_prefix_len``
        boundary — the adapter-independent part grows the shared trunk
        (``lora_id=None``, under the deepest matched trunk node or the root)
        and the adapter-divergent remainder forks under this adapter — each
        span becoming a node owning the (block-aligned part of the) running
        blocks. Partial tail blocks are freed (vLLM-style: only whole blocks
        are shareable). With ``reuse_history_kv=False`` (S-LoRA) all running
        blocks are freed and nothing is inserted.
        """
        blocks = self._running.pop(query_id, [])
        self._running_tokens.pop(query_id, None)
        if not self.config.reuse_history_kv:
            if blocks:
                self.kv_pool.release(Tier.HBM, blocks)
            return None
        m = lookup.match
        if m.lora_node is None:
            if blocks:
                self.kv_pool.release(Tier.HBM, blocks)
            return None
        bs = self.config.block_size
        suffix = tuple(full_tokens)[m.matched_tokens :]
        cache_tokens = (len(suffix) // bs) * bs
        if cache_tokens == 0:
            if blocks:
                self.kv_pool.release(Tier.HBM, blocks)
            return None
        keep_blocks = blocks[: cache_tokens // bs]
        spill = blocks[cache_tokens // bs :]
        if spill:
            self.kv_pool.release(Tier.HBM, spill)
        # classify the committed span at the shared-prefix boundary
        # (lookup.shared_prefix_len is already block-quantized, and 0 when
        # sharing is off): [matched, boundary) is trunk, the rest is fork
        shared_take = 0
        if lookup.shared_prefix_len > m.matched_tokens:
            shared_take = min(
                lookup.shared_prefix_len - m.matched_tokens, cache_tokens
            )
        spans: list[tuple[tuple, Optional[str]]] = []
        if shared_take:
            spans.append((suffix[:shared_take], None))
        if shared_take < cache_tokens:
            spans.append(
                (suffix[shared_take:cache_tokens], m.lora_node.lora_id)
            )
        parent = m.last_node
        node: Optional[Node] = None
        attached: list[Node] = []
        off = 0
        for span_toks, span_lora in spans:
            span_blocks = keep_blocks[off // bs : (off + len(span_toks)) // bs]
            off += len(span_toks)
            node, absorbed = self.tree.insert_kv_ext(
                parent=parent,
                tokens=span_toks,
                size_bytes=len(span_toks) * self.config.kv_bytes_per_token,
                num_blocks=len(span_blocks),
                tier=Residency.HBM,
                now=now,
                lora_id=span_lora,
            )
            # leading span tokens absorbed by pre-existing nodes (divergence
            # below a partially-matched edge, or another adapter already grew
            # this trunk span): our recomputed blocks for that range are
            # redundant — free them, the existing nodes own the data.
            redundant = span_blocks[: absorbed // bs]
            own = span_blocks[absorbed // bs :]
            if redundant:
                self.kv_pool.release(Tier.HBM, redundant)
            if own:
                node.hbm_blocks = own
                node.num_blocks = len(own)
                attached.append(node)
            parent = node
        if self.tracer.enabled:
            for n in attached:
                self.tracer.audit(EV_CACHE_COMMIT, now, node_id=n.node_id,
                                  kind=_audit_kind(n), lora=n.lora_id,
                                  bytes=n.size_bytes, query=query_id)
        # Validity repair: the inserts may have descended through ancestors
        # that were swapped out after this query's lookup (the query
        # recomputed their KVs rather than matching them). Keeping a new
        # node in HBM would violate the validity invariant — demote it.
        # Shallow-first so a demoted trunk span cascades to the fork span
        # just attached below it.
        if self.config.maintain_dependencies:
            for n in attached:
                if n.tier is not Residency.HBM:
                    continue
                p = n.parent
                while p is not None and p.kind is not NodeKind.ROOT:
                    if p.tier is not Residency.HBM:
                        self._swap_out_node(n, now)
                        break
                    p = p.parent
        return node

    @_checked
    def preempt_running(
        self,
        query_id: str,
        lookup: Optional[LookupResult],
        computed_tokens: Sequence[int],
        now: float,
    ) -> Optional[Node]:
        """Demote a preempted victim's running KV into the dependency tree.

        The SLO-tier preemption path: instead of discarding the victim's
        computed work (vLLM-style recompute preemption), its block-aligned
        running KV folds into the tree via the commit path — the blocks
        become ordinary unpinned leaf nodes the scorer can rank and the
        two-tier swapper can demote to host under pressure, and the victim's
        resume lookup matches them back (token-identical resume, swap-in
        instead of recompute). ``computed_tokens`` is the full token prefix
        whose KV the victim actually computed (prompt so far + generated
        minus the pending decode input).

        With ``lookup=None`` (recurrent layouts, whose prefix cache is state
        snapshots — the caller folds a snapshot via :meth:`commit_state`
        separately) or ``reuse_history_kv=False`` (S-LoRA ablation) the
        running blocks are simply released. Either way the query is recorded
        in the preempted registry for the sanitizer's residue check; a later
        :meth:`allocate_running` for the same id (the resume) clears it.
        """
        node: Optional[Node] = None
        if lookup is not None and self.config.reuse_history_kv:
            node = self.commit(query_id, lookup, computed_tokens, now)
        else:
            self.abort_running(query_id)
        self._preempted.add(query_id)
        self.stats.preemptions += 1
        if self.tracer.enabled:
            self.tracer.audit(
                EV_CACHE_PREEMPT, now, query=query_id,
                folded_node=(node.node_id if node is not None else None))
        return node

    def estimate_ttft(self, lora_id: str, history_tokens: Sequence[int],
                      shared_prefix_len: int = 0) -> float:
        """READ-ONLY time-to-first-token estimate for a waiting request.

        Prices the unmatched prefix recompute, host->HBM transfer of any
        host-resident matched KV (or resumable state snapshot), and the
        adapter cold-start — via :func:`admission_ttft_estimate` over a
        non-mutating :meth:`DependencyTree.probe_chain` walk. Deliberately
        NOT :meth:`lookup`: the admission order probes every waiting request
        every step, and lookup touches visit counters / splits edges, which
        would skew the cost model's statistics in proportion to queue depth.
        """
        toks = tuple(history_tokens)
        sq = 0
        if self.config.share_prefix_kv and shared_prefix_len > 0:
            bs = self.config.block_size
            sq = (min(shared_prefix_len, len(toks)) // bs) * bs
        chain = self.tree.probe_chain(lora_id, toks, shared_len=sq)
        host_bytes = 0
        if self.config.state_bytes > 0:
            # recurrent prefix cache: the resume point is the deepest
            # fully-covered payload snapshot; one whole snapshot transfers
            matched = 0
            pos = 0
            tier = None
            for node, cov in chain:
                pos += cov
                if (node.kind is NodeKind.STATE and node.has_payload
                        and cov == node.num_tokens):
                    matched, tier = pos, node.tier
            if tier is Residency.HOST:
                host_bytes += self.config.state_bytes
        else:
            matched = 0
            for node, cov in chain:
                matched += cov
                if node.tier is Residency.HOST:
                    host_bytes += cov * self.config.kv_bytes_per_token
        lnode = self.tree.lora_node(lora_id)
        lora_resident = lnode is not None and lnode.tier is Residency.HBM
        # +1: prefill always recomputes the final prompt token for logits
        return admission_ttft_estimate(
            self.hw,
            new_tokens=len(toks) + 1 - matched,
            host_kv_bytes=host_bytes,
            lora_resident=lora_resident,
            lora_bytes=lnode.size_bytes if lnode is not None else 0,
        )

    @_checked
    def commit_state(
        self, lora_id: str, prefix_tokens: Sequence[int], now: float
    ) -> Optional[Node]:
        """Record a freshly captured recurrent-state snapshot at a boundary.

        Inserts (or reuses) a STATE node covering ``prefix_tokens`` under the
        LoRA branch and allocates ``state_blocks`` HBM blocks for its
        payload, evicting per the scorer on demand. Returns the node — whose
        ``hbm_blocks`` the data plane must now fill via ``StateCache.store``
        — or None when the snapshot is not cacheable: state caching off,
        history reuse disabled (S-LoRA ablation), empty boundary, the
        boundary is already snapshotted, the ancestry is not HBM-resident
        (unlike KV commit, which demotes, an unplaceable snapshot is simply
        dropped — recompute is its backstop), or HBM cannot make room. The
        caller then just discards the captured state.
        """
        if self.config.state_bytes <= 0 or not self.config.reuse_history_kv:
            return None
        toks = tuple(prefix_tokens)
        if not toks:
            return None
        lnode = self.tree.lora_node(lora_id)
        if lnode is None:
            return None
        # insert a hollow husk first; payload is attached only once blocks
        # are secured, so a failed allocation leaves no dangling accounting
        node, absorbed = self.tree.insert_kv_ext(
            parent=lnode, tokens=toks, size_bytes=0, num_blocks=0,
            tier=Residency.HBM, now=now, kind=NodeKind.STATE,
        )
        fresh = absorbed < len(toks)
        if node.kind is not NodeKind.STATE or node.has_payload:
            return None  # boundary collides with a KV node / already cached
        ok = True
        p = node.parent
        while p is not None and p.kind is not NodeKind.ROOT:
            if p.tier is not Residency.HBM:
                ok = False  # ancestor swapped out since the query's lookup
                break
            p = p.parent
        nblocks = self.config.state_blocks
        if ok:
            ok = self._make_room(
                self.kv_pool, nblocks, now, protect={node.node_id}
            )
        if not ok:
            if fresh and not node.children and node.ref_count == 0:
                self.tree.remove(node)  # drop the husk we just created
            return None
        node.hbm_blocks = self.kv_pool.allocate(Tier.HBM, nblocks)
        node.num_blocks = nblocks
        node.size_bytes = self.config.state_bytes
        node.tier = Residency.HBM
        if self.tracer.enabled:
            self.tracer.audit(EV_CACHE_COMMIT, now, node_id=node.node_id,
                              kind=_audit_kind(node), lora=lora_id,
                              bytes=node.size_bytes)
        return node

    # ------------------------------------------------------------- swap core
    def _swap_in_node(
        self, node: Node, now: float, protect: Optional[set[int]] = None
    ) -> Optional[SwapOp]:
        """host -> HBM. Returns None if room cannot be made. ``protect``
        shields additional nodes (the admitting query's working set) from the
        demand evictions this swap-in may trigger."""
        if node.tier is Residency.HBM:
            return SwapOp(SwapKind.SWAP_IN, node.kind, node.lora_id, 0, node_id=node.node_id)
        pool = self._pool_for(node.kind)
        # score sampled pre-mutation so the audit log reflects the state the
        # decision was made in (promotion resets last_access below)
        score = (self.scorer.score(node, now) if self.tracer.enabled
                 else None)
        shield = (protect or set()) | {node.node_id}
        if not self._make_room(pool, node.num_blocks, now, protect=shield):
            return None
        dst = pool.allocate(Tier.HBM, node.num_blocks)
        src = node.host_blocks
        pool.release(Tier.HOST, src)
        node.host_blocks = []
        node.hbm_blocks = dst
        node.tier = Residency.HBM
        node.last_access = now
        self.stats.swap_in_bytes += node.size_bytes
        self.stats.swap_in_count += 1
        op = SwapOp(
            SwapKind.SWAP_IN, node.kind, node.lora_id, node.size_bytes,
            src_blocks=tuple(src), dst_blocks=tuple(dst), node_id=node.node_id,
        )
        self._pending_ops.append(op)
        if self.tracer.enabled:
            self.tracer.audit(EV_CACHE_SWAP_IN, now, node_id=node.node_id,
                              kind=_audit_kind(node), lora=node.lora_id,
                              bytes=node.size_bytes, score=score)
        return op

    def _swap_out_node(self, node: Node, now: float) -> SwapOp:
        """HBM -> host (or drop if the host tier is full)."""
        pool = self._pool_for(node.kind)
        src = node.hbm_blocks
        # every eviction is auditable with the score it was evicted AT:
        # sample before the move mutates tier/blocks
        score = (self.scorer.score(node, now) if self.tracer.enabled
                 else None)
        if pool.can_allocate(Tier.HOST, node.num_blocks):
            dst = pool.allocate(Tier.HOST, node.num_blocks)
            pool.release(Tier.HBM, src)
            node.hbm_blocks = []
            node.host_blocks = dst
            node.tier = Residency.HOST
            self.stats.swap_out_bytes += node.size_bytes
            self.stats.swap_out_count += 1
            op = SwapOp(
                SwapKind.SWAP_OUT, node.kind, node.lora_id, node.size_bytes,
                src_blocks=tuple(src), dst_blocks=tuple(dst), node_id=node.node_id,
            )
            self._pending_ops.append(op)
            if self.tracer.enabled:
                self.tracer.audit(EV_CACHE_SWAP_OUT, now,
                                  node_id=node.node_id,
                                  kind=_audit_kind(node), lora=node.lora_id,
                                  bytes=node.size_bytes, score=score)
            return op
        # host full: drop. KV/STATE nodes are removed (data lost); LoRA nodes
        # keep their tree identity (weights reloadable from disk) with
        # tier=None. A dropped snapshot's blocks vanish with it — its
        # children are self-contained snapshots, unaffected.
        pool.release(Tier.HBM, src)
        node.hbm_blocks = []
        self.stats.drops += 1
        op = SwapOp(
            SwapKind.DROP, node.kind, node.lora_id, node.size_bytes,
            src_blocks=tuple(src), node_id=node.node_id,
        )
        self._pending_ops.append(op)
        if self.tracer.enabled:
            self.tracer.audit(EV_CACHE_DROP, now, node_id=node.node_id,
                              kind=_audit_kind(node), lora=node.lora_id,
                              bytes=node.size_bytes, score=score)
        if node.kind is not NodeKind.LORA and not node.children:
            self.tree.remove(node)
        else:
            node.tier = None
        return op

    def drain_ops(self) -> list[SwapOp]:
        """Return and clear every swap op since the last drain (including
        demand evictions performed inside admit/allocate_running)."""
        ops = self._pending_ops
        self._pending_ops = []
        return ops

    def evict_candidates(self, kind: Optional[NodeKind] = None) -> list[Node]:
        if self.config.maintain_dependencies:
            cands = self.tree.hbm_leaves()
        else:
            cands = [
                n
                for n in self.tree.hbm_nodes()
                if n.ref_count == 0 and n.kind is not NodeKind.ROOT
            ]
        if kind is not None and not self.config.unified_pool:
            cands = [n for n in cands if n.kind is kind]
        return cands

    def _make_room(
        self,
        pool: BlockPool,
        nblocks: int,
        now: float,
        protect: Optional[set[int]] = None,
    ) -> bool:
        """Evict per scorer (ascending Eval) until ``nblocks`` are free."""
        if pool.can_allocate(Tier.HBM, nblocks):
            return True
        self.scorer.refresh(now)
        kind = None
        if not self.config.unified_pool:
            kind = NodeKind.LORA if pool is self.lora_pool else NodeKind.KV
        while not pool.can_allocate(Tier.HBM, nblocks):
            cands = [
                n
                for n in self.evict_candidates(kind)
                if not protect or n.node_id not in protect
            ]
            if not self.config.unified_pool:
                cands = [n for n in cands if self._pool_for(n.kind) is pool]
            if not cands:
                return False
            # node_id tiebreak: equal scores (e.g. cold same-size nodes) must
            # not make victim choice depend on tree-dict insertion order
            victim = min(cands, key=lambda n: (self.scorer.score(n, now), n.node_id))
            if self.tracer.enabled:
                # decision record: the victim's score and the surviving
                # candidates it beat (lowest-scored first)
                ranked = sorted(
                    ((self.scorer.score(n, now), n.node_id) for n in cands
                     if n is not victim))
                self.tracer.audit(
                    EV_CACHE_EVICT, now, node_id=victim.node_id,
                    kind=_audit_kind(victim), lora=victim.lora_id,
                    bytes=victim.size_bytes,
                    score=self.scorer.score(victim, now), reason="demand",
                    beat=[[nid, sc] for sc, nid in ranked[:3]])
            self._swap_out_node(victim, now)
        return True

    # -------------------------------------------------------------- metrics
    def hbm_breakdown(self) -> dict:
        """HBM bytes by category (paper Fig. 14): history KV (per-adapter) /
        shared trunk KV / state snapshots / LoRA / running."""
        bb = self.config.block_bytes
        lora = sum(
            len(n.hbm_blocks) * bb
            for n in self.tree.iter_nodes({NodeKind.LORA})
        )
        kv = 0
        shared = 0
        for n in self.tree.iter_nodes({NodeKind.KV}):
            if n.is_shared:
                shared += len(n.hbm_blocks) * bb
            else:
                kv += len(n.hbm_blocks) * bb
        state = sum(
            len(n.hbm_blocks) * bb
            for n in self.tree.iter_nodes({NodeKind.STATE})
        )
        running = sum(len(b) * bb for b in self._running.values())
        total = (
            self.pool.num_hbm_blocks * bb
            if self.config.unified_pool
            else (self.lora_pool.num_hbm_blocks + self.kv_pool.num_hbm_blocks) * bb
        )
        return {
            "lora_bytes": lora,
            "history_kv_bytes": kv,
            "shared_kv_bytes": shared,
            "state_snapshot_bytes": state,
            "running_kv_bytes": running,
            "total_bytes": total,
        }

    def invalid_kv_fraction(self) -> float:
        total = sum(
            n.size_bytes
            for n in self.tree.iter_nodes({NodeKind.KV, NodeKind.STATE})
            if n.tier is Residency.HBM
        )
        if total == 0:
            return 0.0
        return self.tree.invalid_hbm_bytes() / total

    def check_invariants(self) -> None:
        """Run the full libra-check structural sweep (always-on entry point;
        the legacy pool-partition and validity checks are a subset of it)."""
        check_pool_invariants(self)

    def sanitize_check(self, context: str = "") -> None:
        """Invariant sweep gated on the sanitizer flag — cheap no-op when
        off. Collaborators (the swapper) call this after their own pool
        mutations so sanitize mode covers every mutation site, not just the
        manager's public methods."""
        if self._sanitize:
            check_pool_invariants(self, context=context)
