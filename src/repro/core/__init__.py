"""FASTLIBRA core: dependency-aware cache manager + performance-driven swapper."""

from .block_pool import BlockPool, PoolExhausted, Tier, blocks_for_lora, blocks_for_tokens
from .cache_manager import (
    AdmitResult,
    CacheManager,
    LookupResult,
    ManagerConfig,
    ManagerStats,
    SwapKind,
    SwapOp,
)
from .cost_model import (
    CostModelScorer,
    HardwareModel,
    LRUScorer,
    expected_lora_demand,
    sigmoid,
)
from .dependency_tree import (
    DependencyTree,
    MatchResult,
    Node,
    NodeKind,
    Residency,
)
from .invariants import (
    PoolInvariantError,
    check_pool_invariants,
    dump_tree,
    jit_cache_size,
    sanitize_enabled,
)
from .swapper import CacheSwapper, SwapperConfig, make_fastlibra

__all__ = [
    "AdmitResult",
    "BlockPool",
    "CacheManager",
    "CacheSwapper",
    "CostModelScorer",
    "DependencyTree",
    "HardwareModel",
    "LRUScorer",
    "LookupResult",
    "ManagerConfig",
    "ManagerStats",
    "MatchResult",
    "Node",
    "NodeKind",
    "PoolExhausted",
    "PoolInvariantError",
    "Residency",
    "SwapKind",
    "SwapOp",
    "SwapperConfig",
    "Tier",
    "blocks_for_lora",
    "blocks_for_tokens",
    "check_pool_invariants",
    "dump_tree",
    "expected_lora_demand",
    "jit_cache_size",
    "make_fastlibra",
    "sanitize_enabled",
    "sigmoid",
]
