"""libra-check runtime layer: the pool-invariant sanitizer.

The unified caching pool now spans three object kinds (LoRA adapters,
per-token KV prefixes, recurrent-state snapshots), two tiers, open-query
running blocks, and a scorer-driven eviction loop — and its invariants are
subtle enough that PR 5's hypothesis fuzz caught an admit bug (make-room
evicting a node of the same admission's working set) that no unit test had.
This module makes those invariants *machine-checked*:

:func:`check_pool_invariants` validates the full structural state of a
:class:`~repro.core.cache_manager.CacheManager` — byte-accounting exactness,
parent-residency validity chains, block aliasing/leaks, radix structure,
hollow-STATE interior rules, open-query pin bookkeeping, scorer consistency
— and raises a structured :class:`PoolInvariantError` carrying every
violation plus a dependency-tree dump.

With ``REPRO_SANITIZE=1`` (or ``ManagerConfig(sanitize=True)``) the manager
runs the full pass after **every mutating operation** (lookup/admit/
allocate/commit/abort/swap-sweep), so a corruption is caught at the op that
introduced it, not at whatever later op happens to trip over it. The checks
are pure reads — enabling the sanitizer never changes pool behavior.

This module deliberately has **no top-level imports from the rest of
``repro.core``** (the core modules import :class:`PoolInvariantError` from
here, so a top-level back-import would be a cycle) and no jax dependency:
the jit-cache probe below duck-types on the compiled-function attribute.
"""

from __future__ import annotations

import os
from typing import TYPE_CHECKING, Iterable, Optional

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .cache_manager import CacheManager
    from .dependency_tree import DependencyTree, Node


class PoolInvariantError(AssertionError):
    """A machine-checked pool invariant does not hold.

    Subclasses :class:`AssertionError` so callers (and tests) that guarded
    the old ``assert``-based checks keep working — but unlike ``assert``,
    these raises survive ``python -O``. ``violations`` lists every failed
    invariant from the sweep that raised; ``dump`` is a rendering of the
    dependency tree at failure time.
    """

    def __init__(self, message: str, *, violations: Iterable[str] = (),
                 dump: str = ""):
        self.violations = list(violations) or [message]
        self.dump = dump
        text = message
        if len(self.violations) > 1:
            text += "\n" + "\n".join(f"  - {v}" for v in self.violations)
        if dump:
            text += "\n--- dependency tree at failure ---\n" + dump
        super().__init__(text)


def sanitize_enabled() -> bool:
    """Whether ``REPRO_SANITIZE`` asks for per-op invariant checking."""
    return os.environ.get("REPRO_SANITIZE", "").strip() not in ("", "0")


# --------------------------------------------------------------- tree dump
def dump_tree(tree: "DependencyTree", max_nodes: int = 200) -> str:
    """Human-readable dump of the dependency tree (for error reports)."""
    lines: list[str] = []

    def walk(node: "Node", depth: int) -> None:
        if len(lines) >= max_nodes:
            return
        tier = node.tier.value if node.tier is not None else "-"
        lines.append(
                "  " * depth
                + f"[{node.kind.value}#{node.node_id}] lora={node.lora_id} "
                f"ntok={node.num_tokens} tier={tier} "
                f"hbm={len(node.hbm_blocks)} host={len(node.host_blocks)} "
                f"nblk={node.num_blocks} bytes={node.size_bytes} "
                f"ref={node.ref_count}"
        )
        for child in node.children.values():
            walk(child, depth + 1)

    walk(tree.root, 0)
    if len(lines) >= max_nodes:
        lines.append(f"... (truncated at {max_nodes} nodes)")
    return "\n".join(lines)


# --------------------------------------------------------- jit-cache probe
def jit_cache_size(fn: object) -> int:
    """Number of distinct programs a jitted callable has traced/compiled.

    Duck-types on jax's compiled-function cache probe so this module stays
    jax-free; a plain (un-jitted) callable counts as 0. The compile-count
    regression tests assert bounds on sums of these across an engine's
    jitted entry points — a recompile storm (e.g. a non-static Python
    scalar in a jit signature) shows up as an unbounded count.
    """
    size = getattr(fn, "_cache_size", None)
    if callable(size):
        return int(size())
    return 0


# ------------------------------------------------------------- the checks
def _iter_pools(mgr: "CacheManager"):
    """(name, pool) pairs, unique by identity (non-unified mode has two)."""
    seen: dict[int, str] = {}
    for name, pool in (("pool", mgr.pool), ("lora_pool", mgr.lora_pool),
                       ("kv_pool", mgr.kv_pool)):
        if id(pool) not in seen:
            seen[id(pool)] = name
            yield name, pool


def _check_pool_partition(mgr: "CacheManager", out: list[str]) -> None:
    """I-pool: per tier, free and allocated ids partition [0, total)."""
    from .block_pool import Tier

    for name, pool in _iter_pools(mgr):
        for tier, total in ((Tier.HBM, pool.num_hbm_blocks),
                            (Tier.HOST, pool.num_host_blocks)):
            free = set(pool._free[tier])
            alloc = pool._allocated[tier]
            if len(free) != len(pool._free[tier]):
                out.append(f"pool-partition: {name}/{tier.value} free list "
                           f"has duplicate ids")
            if not free.isdisjoint(alloc):
                out.append(f"pool-partition: {name}/{tier.value} "
                           f"double-booked blocks {sorted(free & alloc)[:8]}")
            if len(free) + len(alloc) != total or free | alloc != set(range(total)):
                out.append(
                    f"pool-partition: {name}/{tier.value} id space corrupt "
                    f"(free={len(free)} alloc={len(alloc)} total={total})")


def _check_tier_residency(mgr: "CacheManager", out: list[str]) -> None:
    """I-tier: a node's block lists agree with its residency tier."""
    from .dependency_tree import NodeKind, Residency

    for n in mgr.tree.iter_nodes():
        if n.tier is Residency.HBM and n.host_blocks:
            out.append(f"tier-residency: HBM node #{n.node_id} owns "
                       f"{len(n.host_blocks)} host blocks")
        if n.tier is Residency.HOST and n.hbm_blocks:
            out.append(f"tier-residency: host node #{n.node_id} owns "
                       f"{len(n.hbm_blocks)} HBM blocks")
        if n.tier is None and (n.hbm_blocks or n.host_blocks):
            out.append(f"tier-residency: dropped node #{n.node_id} still "
                       f"owns data-plane blocks")
        if n.kind in (NodeKind.KV, NodeKind.LORA) and n.tier is not None:
            held = len(n.hbm_blocks) + len(n.host_blocks)
            if held != n.num_blocks:
                out.append(
                    f"tier-residency: {n.kind.value} node #{n.node_id} "
                    f"num_blocks={n.num_blocks} but holds {held}")


def _check_validity_chain(mgr: "CacheManager", out: list[str]) -> None:
    """I-validity: HBM node => parent HBM (no HBM payload under a host
    ancestor) — the paper's zero-invalid-KV property. Dependency-maintained
    managers only; baselines (WOM/vLLM) violate this by design."""
    from .dependency_tree import NodeKind, Residency

    if not mgr.config.maintain_dependencies:
        return
    for n in mgr.tree.iter_nodes():
        if n.tier is Residency.HBM and n.parent is not None:
            p = n.parent
            if not (p.kind is NodeKind.ROOT or p.tier is Residency.HBM):
                out.append(
                    f"validity-chain: HBM node #{n.node_id} "
                    f"({n.kind.value}, lora={n.lora_id}) under "
                    f"non-resident parent #{p.node_id} (tier={p.tier})")
    bad = mgr.tree.invalid_hbm_bytes()
    if bad:
        out.append(f"validity-chain: {bad} invalid HBM bytes "
                   f"(dependency-maintained manager must report 0)")


def _owned_blocks(mgr: "CacheManager"):
    """(hbm_by_pool, host_owned) maps of every owned block with its owner."""
    hbm: dict[int, dict[int, str]] = {}
    host: dict[int, str] = {}
    dup: list[str] = []

    def own(table: dict[int, str], b: int, owner: str) -> None:
        if b in table:
            dup.append(f"block-aliasing: block {b} owned by both "
                       f"{table[b]} and {owner}")
        else:
            table[b] = owner

    for n in mgr.tree.iter_nodes():
        pool = mgr._pool_for(n.kind)
        tab = hbm.setdefault(id(pool), {})
        for b in n.hbm_blocks:
            own(tab, b, f"node#{n.node_id}")
        for b in n.host_blocks:
            own(host, b, f"node#{n.node_id}")
    kv_tab = hbm.setdefault(id(mgr.kv_pool), {})
    for qid, blocks in mgr._running.items():
        for b in blocks:
            own(kv_tab, b, f"running:{qid}")
    return hbm, host, dup


def _check_block_ownership(mgr: "CacheManager", out: list[str]) -> None:
    """I-alias + I-leak: every owned block is allocated exactly once, and
    every allocated block has exactly one owner (tree node or running
    query) — byte accounting is exact, not merely bounded."""
    from .block_pool import Tier

    hbm_by_pool, host_owned, dup = _owned_blocks(mgr)
    out.extend(dup)
    for name, pool in _iter_pools(mgr):
        owned = hbm_by_pool.get(id(pool), {})
        alloc = pool._allocated[Tier.HBM]
        missing = set(owned) - alloc
        orphan = alloc - set(owned)
        if missing:
            out.append(f"block-ownership: {name}/hbm owned-but-unallocated "
                       f"{sorted(missing)[:8]}")
        if orphan:
            out.append(f"block-ownership: {name}/hbm allocated-but-unowned "
                       f"(leaked) {sorted(orphan)[:8]}")
    # host free/allocated structures are shared between pools in the
    # non-unified layout, so the host tier is checked once via mgr.pool
    host_alloc = mgr.pool._allocated[Tier.HOST]
    missing = set(host_owned) - host_alloc
    orphan = host_alloc - set(host_owned)
    if missing:
        out.append(f"block-ownership: host owned-but-unallocated "
                   f"{sorted(missing)[:8]}")
    if orphan:
        out.append(f"block-ownership: host allocated-but-unowned (leaked) "
                   f"{sorted(orphan)[:8]}")


def _check_byte_accounting(mgr: "CacheManager", out: list[str]) -> None:
    """I-bytes: hbm_breakdown() component sums == block-pool used bytes ==
    per-node block sums, *exactly*."""
    from .block_pool import Tier

    bb = mgr.config.block_bytes
    bd = mgr.hbm_breakdown()
    comp = (bd["lora_bytes"] + bd["history_kv_bytes"] + bd["shared_kv_bytes"]
            + bd["state_snapshot_bytes"] + bd["running_kv_bytes"])
    pool_used = sum(
        (pool.num_hbm_blocks - len(pool._free[Tier.HBM])) * bb
        for _, pool in _iter_pools(mgr)
    )
    node_sum = sum(len(n.hbm_blocks) for n in mgr.tree.iter_nodes()) * bb
    node_sum += sum(len(b) for b in mgr._running.values()) * bb
    if comp != pool_used:
        out.append(f"byte-accounting: breakdown components sum to {comp} "
                   f"but block pools have {pool_used} HBM bytes in use")
    if node_sum != pool_used:
        out.append(f"byte-accounting: per-node HBM bytes {node_sum} != "
                   f"pool used bytes {pool_used}")
    if comp > bd["total_bytes"]:
        out.append(f"byte-accounting: used {comp} exceeds capacity "
                   f"{bd['total_bytes']}")


def _check_radix_structure(mgr: "CacheManager", out: list[str]) -> None:
    """I-radix: child keys match edge labels, parent pointers are
    consistent, KV edges are align-quantized, siblings never share an
    align-chunk prefix (match/split determinism depends on this)."""
    from .dependency_tree import NodeKind

    tree = mgr.tree
    align = tree.align
    stack = [tree.root]
    while stack:
        n = stack.pop()
        for key, child in n.children.items():
            if child.parent is not n:
                out.append(f"radix-structure: child #{child.node_id} of "
                           f"#{n.node_id} has parent pointer "
                           f"{child.parent and child.parent.node_id}")
            if child.kind is NodeKind.LORA:
                if key != child.node_id:
                    out.append(f"radix-structure: LoRA node #{child.node_id}"
                               f" keyed by {key!r}, expected its node_id")
            else:
                if not child.tokens:
                    out.append(f"radix-structure: {child.kind.value} node "
                               f"#{child.node_id} has an empty edge label")
                elif key != tree._child_key(n, child.lora_id, child.tokens):
                    out.append(
                        f"radix-structure: node #{child.node_id} keyed by "
                        f"{key!r} but expected "
                        f"{tree._child_key(n, child.lora_id, child.tokens)!r}")
                if child.kind is NodeKind.KV and len(child.tokens) % align:
                    out.append(f"radix-structure: KV node #{child.node_id} "
                               f"edge length {len(child.tokens)} not a "
                               f"multiple of align={align}")
            stack.append(child)


def _check_lora_registry(mgr: "CacheManager", out: list[str]) -> None:
    """I-lora: the LoRA registry and the second tree layer agree, and every
    prefix node's lora_id is consistent with its parent — inherited inside a
    branch, forking (adapter label under a shared parent) only at the trunk
    boundary, and never adapter-labelled directly under the root."""
    from .dependency_tree import NodeKind

    tree = mgr.tree
    layer = {n.node_id: n for n in tree.root.children.values()}
    for lid, node in tree._lora_nodes.items():
        if node.kind is not NodeKind.LORA or node.lora_id != lid:
            out.append(f"lora-registry: registry entry {lid!r} points at "
                       f"{node.kind.value} node #{node.node_id} "
                       f"(lora_id={node.lora_id!r})")
        if node.node_id not in layer:
            out.append(f"lora-registry: {lid!r} node #{node.node_id} is not "
                       f"a child of the root")
    for n in tree.iter_nodes():
        if n.kind is NodeKind.LORA:
            if tree._lora_nodes.get(n.lora_id) is not n:
                out.append(f"lora-registry: LoRA node #{n.node_id} "
                           f"({n.lora_id!r}) missing from the registry")
            continue
        p = n.parent
        if p is None:
            continue
        if p.kind is NodeKind.ROOT:
            if n.lora_id is not None:
                out.append(f"lora-registry: adapter-labelled node "
                           f"#{n.node_id} (lora={n.lora_id!r}) directly "
                           f"under the root")
        elif p.lora_id is not None:
            # inside a LoRA branch or an adapter fork: labels inherit
            if n.lora_id != p.lora_id:
                out.append(f"lora-registry: node #{n.node_id} labelled "
                           f"lora={n.lora_id!r} lives under branch "
                           f"{p.lora_id!r}")
        elif n.lora_id is not None and n.lora_id not in tree._lora_nodes:
            # fork root off the shared trunk: its adapter must be registered
            out.append(f"lora-registry: fork root #{n.node_id} references "
                       f"unregistered adapter {n.lora_id!r}")


def _check_shared_prefix(mgr: "CacheManager", out: list[str]) -> None:
    """I-shared: shared-trunk structure. Trunk nodes are KV-kind with
    ``lora_id=None`` and live only under the root or another trunk node;
    no trunk exists when sharing is disabled; STATE never forks off the
    trunk; every fork root hangs off a live (root-reachable) shared parent
    under its composite child key; and ``hbm_breakdown()`` splits
    ``shared_kv_bytes`` exactly."""
    from .dependency_tree import NodeKind

    tree = mgr.tree
    bb = mgr.config.block_bytes
    shared_blocks = 0
    for n in tree.iter_nodes():
        if n.kind is not NodeKind.LORA and n.lora_id is None:
            if n.kind is not NodeKind.KV:
                out.append(f"shared-prefix: {n.kind.value} node #{n.node_id}"
                           f" carries lora_id=None (trunk is KV-only)")
                continue
            shared_blocks += len(n.hbm_blocks)
            if not mgr.config.share_prefix_kv:
                out.append(f"shared-prefix: trunk node #{n.node_id} exists "
                           f"with share_prefix_kv disabled")
            p = n.parent
            if p is not None and not (p.kind is NodeKind.ROOT
                                      or (p.kind is NodeKind.KV
                                          and p.lora_id is None)):
                out.append(f"shared-prefix: trunk node #{n.node_id} under "
                           f"non-trunk parent #{p.node_id} "
                           f"({p.kind.value}, lora={p.lora_id!r})")
        elif (n.parent is not None and n.parent.kind is NodeKind.KV
              and n.parent.lora_id is None):
            # adapter fork root off the shared trunk
            if n.kind is NodeKind.STATE:
                out.append(f"shared-prefix: STATE snapshot #{n.node_id} "
                           f"forks off the shared trunk")
            top = n.parent
            while top.parent is not None:
                top = top.parent
            if top is not tree.root:
                out.append(f"shared-prefix: fork root #{n.node_id} "
                           f"references a detached shared parent "
                           f"#{n.parent.node_id}")
            key = tree._child_key(n.parent, n.lora_id, n.tokens)
            if n.parent.children.get(key) is not n:
                out.append(f"shared-prefix: fork root #{n.node_id} not "
                           f"reachable from its shared parent under key "
                           f"{key!r}")
    want = shared_blocks * bb
    got = mgr.hbm_breakdown()["shared_kv_bytes"]
    if got != want:
        out.append(f"shared-prefix: hbm_breakdown shared_kv_bytes={got} but "
                   f"trunk nodes own {want} HBM bytes")


def _check_hollow_state(mgr: "CacheManager", out: list[str]) -> None:
    """I-state: snapshot payloads are whole (exactly state_blocks in exactly
    one tier) and hollow interiors are pure trie structure."""
    from .dependency_tree import NodeKind

    sb = mgr.config.state_blocks
    for n in mgr.tree.iter_nodes({NodeKind.STATE}):
        if n.has_payload:
            if n.hbm_blocks and n.host_blocks:
                out.append(f"hollow-state: snapshot #{n.node_id} split "
                           f"across tiers")
            held = len(n.hbm_blocks or n.host_blocks)
            if held != sb or n.num_blocks != sb:
                out.append(
                    f"hollow-state: snapshot #{n.node_id} holds {held} "
                    f"blocks (num_blocks={n.num_blocks}), expected {sb} — "
                    f"snapshots are fixed-size and indivisible")
        else:
            # a hollow interior owns nothing; a dropped snapshot keeps its
            # nominal num_blocks only with tier=None
            if not (n.num_blocks == 0 or n.tier is None):
                out.append(f"hollow-state: payload-less STATE #{n.node_id} "
                           f"claims num_blocks={n.num_blocks} with "
                           f"tier={n.tier}")


def _check_pin_bookkeeping(mgr: "CacheManager", out: list[str]) -> None:
    """I-pin: ref counts are non-negative and every open query's running
    block list matches its recorded token count exactly (the abort path
    must leave no residue)."""
    for n in mgr.tree.iter_nodes():
        if n.ref_count < 0:
            out.append(f"pin-bookkeeping: node #{n.node_id} ref_count="
                       f"{n.ref_count}")
    for qid in mgr._running_tokens:
        if qid not in mgr._running:
            out.append(f"pin-bookkeeping: query {qid!r} has a token count "
                       f"but no running block list")
    for qid, blocks in mgr._running.items():
        toks = mgr._running_tokens.get(qid, 0)
        want = mgr.kv_blocks_for(toks) if toks else 0
        if len(blocks) != want:
            out.append(f"pin-bookkeeping: query {qid!r} holds {len(blocks)} "
                       f"running blocks for {toks} tokens (expected {want})")


def _check_scorer_consistency(mgr: "CacheManager", out: list[str]) -> None:
    """I-score: the eviction scorer is usable — every candidate the swapper
    could pick scores to a finite, repeatable value, and the structural
    leaf/root candidate predicates actually hold for what the tree
    enumerates. A NaN (or nondeterministic) score silently scrambles
    ascending-Eval eviction order."""
    import math

    now = max((n.last_access for n in mgr.tree.iter_nodes()), default=0.0)
    for n in mgr.tree.hbm_leaves():
        if n.hbm_children() or n.ref_count != 0:
            out.append(f"scorer-consistency: hbm_leaves() returned "
                       f"#{n.node_id} which is not an unpinned HBM leaf")
        s1 = mgr.scorer.score(n, now)
        s2 = mgr.scorer.score(n, now)
        if not math.isfinite(s1):
            out.append(f"scorer-consistency: non-finite score {s1!r} for "
                       f"candidate #{n.node_id}")
        elif s1 != s2:
            out.append(f"scorer-consistency: score for #{n.node_id} is not "
                       f"repeatable ({s1!r} != {s2!r})")
    for n in mgr.tree.host_roots():
        if n.parent is None or not n.is_host_root():
            out.append(f"scorer-consistency: host_roots() returned "
                       f"#{n.node_id} which is not a host root")


def _check_preempted_residue(mgr: "CacheManager", out: list[str]) -> None:
    """I-preempt: a preempted query left nothing behind in the running set.

    ``preempt_running`` demotes the victim's computed KV into the tree (or
    releases it) and records the query in ``_preempted``; until a resume
    ``allocate_running`` clears the mark, the query must hold zero running
    blocks and zero running-token bookkeeping — a leak here is exactly the
    "preemption discards the bookkeeping but not the blocks" failure mode
    this family exists to catch. The folded KV itself must be demotable:
    preemption never leaves it pinned (ref_count is the engine's admission
    pin, which the engine drops before preempting)."""
    for qid in mgr._preempted:
        if mgr._running.get(qid):
            out.append(f"preempted-residue: query {qid!r} was preempted but "
                       f"still holds {len(mgr._running[qid])} running blocks")
        if mgr._running_tokens.get(qid, 0):
            out.append(f"preempted-residue: query {qid!r} was preempted but "
                       f"still has running token count "
                       f"{mgr._running_tokens[qid]}")


_CHECKS = (
    _check_pool_partition,
    _check_tier_residency,
    _check_validity_chain,
    _check_block_ownership,
    _check_byte_accounting,
    _check_radix_structure,
    _check_lora_registry,
    _check_shared_prefix,
    _check_hollow_state,
    _check_pin_bookkeeping,
    _check_scorer_consistency,
    _check_preempted_residue,
)


def check_pool_invariants(mgr: "CacheManager", context: str = "") -> None:
    """Run every structural invariant over ``mgr``; raise a structured
    :class:`PoolInvariantError` (with a tree dump) if any fail.

    Pure reads only — safe to call at any quiescent point (the manager's
    sanitize hooks call it after every mutating public operation).
    """
    violations: list[str] = []
    for check in _CHECKS:
        check(mgr, violations)
    if violations:
        where = f" after {context}" if context else ""
        raise PoolInvariantError(
            f"{len(violations)} pool invariant violation(s){where}",
            violations=violations,
            dump=dump_tree(mgr.tree),
        )
