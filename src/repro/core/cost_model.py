"""Performance-driven cost model (FASTLIBRA §5).

Implements Equations 3–6 of the paper:

  Low_lora        = Σ_i (1 − (1 − prob_i)^BS)                         (Eq. 3)
  LoRA_Eval_i     = max(1, Low_lora / Now_lora)        (LoRA nodes)   (Eq. 4)
  Retain_Eval_i   = cost_i · prob_i · (1 − sigmoid(t_i))              (Eq. 5)
  Eval_i          = LoRA_Eval_i · Retain_Eval_i                       (Eq. 6)

``cost_i`` is the node's swap (transfer) cost in seconds = bytes / PCIe bw;
``prob_i`` the decayed visit-frequency share recorded on the dependency tree;
``t_i`` the time since last use. The paper does not state a time scale for
the sigmoid forget gate — we introduce ``sigmoid_tau`` (default 15 s, tuned — see EXPERIMENTS.md §Perf-policy) so that
``sigmoid(t_i / tau)`` spans its dynamic range over realistic inter-arrival
gaps; this is recorded as an assumption in DESIGN.md.

A node with a *higher* ``Eval`` benefits TTFT more when retained in HBM, so
swap-out consumes candidates in ascending order and swap-in in descending
order (§5.3).

Scorers are pluggable so the ablations drop in cleanly:
  * :class:`CostModelScorer` — full FASTLIBRA (Eq. 6).
  * ``CostModelScorer(lora_reward=False)`` — FASTLIBRA-WOL (Eq. 4 removed).
  * :class:`LRUScorer` — FASTLIBRA-WOS / vLLM-style LRU ordering.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Protocol

from .dependency_tree import DependencyTree, Node, NodeKind


def sigmoid(x: float) -> float:
    if x >= 0:
        z = math.exp(-x)
        return 1.0 / (1.0 + z)
    z = math.exp(x)
    return z / (1.0 + z)


@dataclasses.dataclass
class HardwareModel:
    """Host↔HBM link + accelerator constants used for swap-cost estimates.

    Defaults follow the paper's platform (Table 1): PCIe 4.0 ×16 ≈ 32 GB/s
    raw, ~26 GB/s effective; NPU 256 TFLOPS fp16 with 64 GB HBM.
    """

    pcie_bw_bytes: float = 2e9  # effective copy bw (see sim.hardware.NPUSpec)
    pcie_latency_s: float = 10e-6
    hbm_bytes: int = 64 * 1024**3
    host_bytes: int = 256 * 1024**3
    flops_fp16: float = 256e12
    hbm_bw_bytes: float = 1.6e12  # HBM2e-class NPU
    # per-token prefill compute time — the recompute a retained state
    # snapshot saves (paper-platform scale: ~2·7e9 FLOPs/token at 55% MFU of
    # 256 TFLOPS ≈ 1e-4 s). The simulator overrides this from its deployed
    # model's roofline.
    prefill_s_per_token: float = 1e-4

    def transfer_cost(self, nbytes: int) -> float:
        return self.pcie_latency_s + nbytes / self.pcie_bw_bytes

    def recompute_cost(self, n_tokens: int) -> float:
        """Prefill cost of recomputing an ``n_tokens`` prefix from scratch."""
        return n_tokens * self.prefill_s_per_token


def admission_ttft_estimate(
    hw: HardwareModel,
    *,
    new_tokens: int,
    host_kv_bytes: int = 0,
    lora_resident: bool = True,
    lora_bytes: int = 0,
) -> float:
    """Estimated time-to-first-token for a WAITING request (SLO admission).

    The same components Eqs. 3–6 price for retention, viewed from the other
    side: prefix recompute for the unmatched suffix, swap-in transfer for any
    host-resident matched KV/state, and the adapter cold-start when its LoRA
    is not HBM-resident. Deadline-aware admission ranks waiting requests by
    ``deadline - now - estimate`` (least slack first within a priority tier),
    so a request whose cached prefix makes it cheap to serve jumps ahead of
    one that must recompute everything.
    """
    cost = hw.recompute_cost(max(0, new_tokens))
    if host_kv_bytes > 0:
        cost += hw.transfer_cost(host_kv_bytes)
    if not lora_resident and lora_bytes > 0:
        cost += hw.transfer_cost(lora_bytes)
    return cost


def expected_lora_demand(probs: list[float], batch_size: float) -> float:
    """Eq. 3 — expected number of distinct LoRAs present in a recent batch.

    ``batch_size`` is the engine's unified mixed-batch load: per-step REAL
    token count (decode rows 1 token, prefill rows their chunk), averaged
    over the last 5 s. The paper states Eq. 3 over a request count; tokens
    are the mixed-scheduler generalization — monotone in load, identical
    when every row is a 1-token decode row — so Low_lora saturates toward
    the full adapter set exactly when the batch is actually busy."""
    bs = max(0.0, batch_size)
    return sum(1.0 - (1.0 - min(1.0, max(0.0, p))) ** bs for p in probs)


class NodeScorer(Protocol):
    def score(self, node: Node, now: float) -> float:
        """Higher ⇒ more valuable to retain in HBM."""
        ...

    def refresh(self, now: float) -> None:
        """Recompute batch-level terms (Low_lora etc.) before a sweep."""
        ...


class CostModelScorer:
    """Eq. 6 scorer over the dependency tree."""

    def __init__(
        self,
        tree: DependencyTree,
        hardware: HardwareModel,
        *,
        lora_reward: bool = True,
        sigmoid_tau: float = 15.0,
        density_ordering: bool = True,
    ):
        self.tree = tree
        self.hw = hardware
        self.lora_reward = lora_reward
        self.sigmoid_tau = sigmoid_tau
        # Beyond-paper correction (EXPERIMENTS.md §Perf-policy): the paper
        # orders candidates by Eval_i directly, but Eval_i ∝ cost_i ∝ bytes,
        # so large cold nodes dominate small hot ones. Greedy knapsack should
        # rank by value *density* Eval_i / bytes. density_ordering=False
        # reproduces the paper-literal ordering for the ablation.
        self.density_ordering = density_ordering
        self._lora_eval = 1.0
        self._recent_batch_size = 0.0

    # The engine/simulator reports the recent average batch load (last 5 s,
    # §5.1) before each swapper sweep — the unified mixed-batch token count
    # under the Sarathi-style scheduler (see expected_lora_demand).
    def observe_batch_size(self, bs: float) -> None:
        self._recent_batch_size = bs

    def refresh(self, now: float) -> None:
        if not self.lora_reward:
            self._lora_eval = 1.0
            return
        probs = [self.tree.visit_prob(n, now) for n in self.tree.lora_nodes()]
        low_lora = expected_lora_demand(probs, self._recent_batch_size)
        now_lora = max(1, self.tree.resident_lora_count())
        self._lora_eval = max(1.0, low_lora / now_lora)

    @property
    def low_lora(self) -> float:
        probs = [self.tree.visit_prob(n, 0.0) for n in self.tree.lora_nodes()]
        return expected_lora_demand(probs, self._recent_batch_size)

    def retain_eval(self, node: Node, now: float) -> float:
        if node.kind is NodeKind.STATE:
            if not node.has_payload:
                # hollow radix interior: nothing to retain, evict first
                return 0.0
            # A snapshot's retention benefit is the recompute it saves — the
            # full-prefix prefill cost — not its (tiny, fixed) byte transfer
            # cost: one O(1) snapshot replaces an O(n) prefix recompute.
            cost = self.hw.recompute_cost(node.path_num_tokens())
        elif node.is_shared:
            # A shared trunk node is a dependency of fork KV under every
            # adapter below it: dropping it invalidates all of them, so its
            # retention value is the larger of its own reload cost and the
            # summed per-fork recompute of the prefix it carries.
            n_deps = max(1, len(self.tree.dependent_fork_loras(node)))
            cost = max(
                self.hw.transfer_cost(node.size_bytes),
                n_deps * self.hw.recompute_cost(node.path_num_tokens()),
            )
        else:
            cost = self.hw.transfer_cost(node.size_bytes)
        prob = self.tree.visit_prob(node, now)
        t = max(0.0, now - node.last_access)
        decay = 1.0 - sigmoid(t / self.sigmoid_tau)
        return cost * prob * decay

    def score(self, node: Node, now: float) -> float:
        ev = self.retain_eval(node, now)
        if node.kind is NodeKind.LORA:
            ev *= self._lora_eval
        if self.density_ordering:
            ev /= max(1, node.size_bytes)
        return ev


class LRUScorer:
    """Plain LRU ordering (FASTLIBRA-WOS ablation & vLLM baseline).

    Score = last access time: most-recently-used retained first.
    """

    def __init__(self, tree: DependencyTree):
        self.tree = tree

    def refresh(self, now: float) -> None:  # noqa: D102 - protocol
        pass

    def observe_batch_size(self, bs: float) -> None:
        pass

    def score(self, node: Node, now: float) -> float:
        return node.last_access
