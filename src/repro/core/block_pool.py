"""Unified block pool for LoRAs and KV caches (FASTLIBRA §4.3).

Both HBM and host memory are partitioned into fixed-size blocks at init. KV
caches occupy whole blocks (``block_size`` tokens per block); LoRA adapters are
partitioned block-wise **along the rank dimension** so that every other
dimension aligns with the KV layout — one rank-block of a LoRA owns exactly one
pool block. This is what makes a *unified* pool possible (no fragmentation
between the two object kinds), mirroring the paper's extension of vLLM's
BlockManager.

The pool is a pure control-plane object: it hands out integer block ids per
tier. The data plane (``repro/kvcache``, ``repro/lora``) maps block ids to
slices of device/host arrays; the simulator maps them to byte accounting only.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable

from .invariants import PoolInvariantError


class Tier(enum.Enum):
    """Memory tier a block lives in."""

    HBM = "hbm"
    HOST = "host"


class PoolExhausted(Exception):
    """Raised when an allocation cannot be satisfied in the requested tier."""


@dataclasses.dataclass
class PoolStats:
    hbm_total: int
    hbm_free: int
    host_total: int
    host_free: int

    @property
    def hbm_used(self) -> int:
        return self.hbm_total - self.hbm_free

    @property
    def hbm_usage(self) -> float:
        return 0.0 if self.hbm_total == 0 else self.hbm_used / self.hbm_total

    @property
    def host_used(self) -> int:
        return self.host_total - self.host_free


class BlockPool:
    """Two-tier (HBM + host) unified block allocator.

    Blocks are identified by dense integer ids per tier (``0..n_tier-1``);
    free-lists are LIFO so recently-freed blocks are reused first (better
    locality for the data plane's physical arrays).
    """

    def __init__(self, num_hbm_blocks: int, num_host_blocks: int, block_bytes: int):
        if num_hbm_blocks <= 0:
            raise ValueError("num_hbm_blocks must be positive")
        if num_host_blocks < 0:
            raise ValueError("num_host_blocks must be >= 0")
        self.num_hbm_blocks = num_hbm_blocks
        self.num_host_blocks = num_host_blocks
        self.block_bytes = block_bytes
        self._free: dict[Tier, list[int]] = {
            Tier.HBM: list(range(num_hbm_blocks - 1, -1, -1)),
            Tier.HOST: list(range(num_host_blocks - 1, -1, -1)),
        }
        self._allocated: dict[Tier, set[int]] = {Tier.HBM: set(), Tier.HOST: set()}

    # ------------------------------------------------------------------ alloc
    def free_blocks(self, tier: Tier) -> int:
        return len(self._free[tier])

    def can_allocate(self, tier: Tier, n: int) -> bool:
        return len(self._free[tier]) >= n

    def allocate(self, tier: Tier, n: int) -> list[int]:
        """Allocate ``n`` blocks in ``tier``; all-or-nothing."""
        free = self._free[tier]
        if len(free) < n:
            raise PoolExhausted(
                f"need {n} blocks in {tier.value}, only {len(free)} free"
            )
        out = [free.pop() for _ in range(n)]
        self._allocated[tier].update(out)
        return out

    def release(self, tier: Tier, block_ids: Iterable[int]) -> None:
        allocd = self._allocated[tier]
        for b in block_ids:
            if b not in allocd:
                raise KeyError(f"block {b} not allocated in {tier.value}")
            allocd.remove(b)
            self._free[tier].append(b)

    # ------------------------------------------------------------------ stats
    def stats(self) -> PoolStats:
        return PoolStats(
            hbm_total=self.num_hbm_blocks,
            hbm_free=len(self._free[Tier.HBM]),
            host_total=self.num_host_blocks,
            host_free=len(self._free[Tier.HOST]),
        )

    def hbm_usage(self) -> float:
        return self.stats().hbm_usage

    def check_invariants(self) -> None:
        """Invariant: free + allocated partitions the id space. Raises (not
        asserts — this must survive ``python -O``) on corruption."""
        for tier, total in ((Tier.HBM, self.num_hbm_blocks), (Tier.HOST, self.num_host_blocks)):
            free = set(self._free[tier])
            alloc = self._allocated[tier]
            if not free.isdisjoint(alloc):
                raise PoolInvariantError(f"{tier}: double-booked blocks")
            if len(free) + len(alloc) != total:
                raise PoolInvariantError(f"{tier}: leaked blocks")
            if free | alloc != set(range(total)):
                raise PoolInvariantError(f"{tier}: id space corrupt")


def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
    """Number of KV blocks needed for ``num_tokens`` tokens."""
    return -(-num_tokens // block_size)


def blocks_for_lora(rank: int, rank_block: int) -> int:
    """Number of pool blocks a LoRA of ``rank`` occupies (rank-dim paging)."""
    return -(-rank // rank_block)
