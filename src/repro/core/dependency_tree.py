"""Usage-dependency tree (FASTLIBRA §4) with a shared base-model trunk.

A radix/trie structure over LoRAs, shared base-model prefixes, and
adapter-specific KV-cache prefixes:

* layer 0: a single virtual root (always "resident"),
* layer 1a: one node per LoRA adapter (the paper's "second layer"),
* layer 1b: a **shared radix trunk** of adapter-independent KV nodes
  (``lora_id=None``) directly under the root — base-model KV for spans the
  request declared adapter-independent (system prompts computed with the
  adapter inactive, A-LoRA / LRAgent style). Trunk nodes are cached ONCE and
  may carry fork children under *multiple* adapters,
* below each LoRA node — or forking off a trunk node via a composite
  ``(lora_id, chunk)`` child key — a radix trie of adapter-divergent KV
  prefixes produced by queries that used that LoRA. Each root→leaf path is a
  conversation record; siblings share their parent prefix. For recurrent
  architectures (RWKV-6, RG-LRU) the prefix nodes are fixed-size **state
  snapshots** (:attr:`NodeKind.STATE`) instead of per-token KV — same trie,
  same residency/eviction machinery, but the payload is indivisible (see
  :meth:`DependencyTree._split`). STATE never lives on the shared trunk.

The resulting shape is root → shared trunk (optional) → per-adapter forks,
so a thousand adapters serving one product system prompt cache the prefix
once instead of a thousand times. A trunk node's structural children are its
dependents: evicting it invalidates forks under every adapter below it,
which is why the cost model prices shared nodes by the *sum* of
dependent-fork recompute (see ``cost_model.CostModelScorer``).

Every node carries the statistics the cost model (§5.2) needs: visit
frequency (exponentially decayed), last-recent-use time, size in blocks/bytes
and swap (transfer) cost. Residency is per-node (HBM / HOST); the structural
invariant maintained by the cache manager is

    node.tier == HBM  ⇒  node.parent.tier == HBM          (validity invariant)

which is exactly "no invalid KV": a KV prefix is only HBM-resident if its
whole ancestry — its LoRA, or the shared trunk above its fork point — is.
A shared trunk node is valid with no LoRA ancestor at all (its parent chain
terminates at the root). Swap-out therefore only targets *HBM leaves* (HBM
nodes with no HBM children), swap-in only *host roots* (host nodes whose
parent is already in HBM).

The tree is pure control plane: payloads are opaque block-id lists owned by
the manager. ``align`` (tokens) quantizes match/split points so node spans
stay block-aligned when the data plane requires it (align = kv block size).
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Callable, Iterator, Optional, Sequence

from .invariants import PoolInvariantError

Token = int
TokenSeq = tuple[Token, ...]


class NodeKind(enum.Enum):
    ROOT = "root"
    LORA = "lora"
    KV = "kv"  # per-token KV-cache prefix node (attention archs)
    # Recurrent-state snapshot node (RWKV / RG-LRU): the fixed-size model
    # state at the prefix boundary this node's path ends on. Unlike KV, the
    # payload is indivisible and lives entirely on the node whose boundary it
    # was captured at; radix splits therefore create *hollow* STATE interiors
    # (no blocks) that are pure trie structure — resumable boundaries are the
    # STATE nodes with payload blocks.
    STATE = "state"


class Residency(enum.Enum):
    HBM = "hbm"
    HOST = "host"


_node_ids = itertools.count()

# sentinel: insert_kv_ext inherits the parent's lora_id unless told otherwise
_INHERIT: object = object()


@dataclasses.dataclass
class Node:
    kind: NodeKind
    lora_id: Optional[str]  # which LoRA branch this node belongs to (None for root)
    tokens: TokenSeq  # edge label (empty for root/LoRA nodes)
    tier: Optional[Residency]
    parent: Optional["Node"] = None
    node_id: int = dataclasses.field(default_factory=lambda: next(_node_ids))
    # children keyed by the first ``align`` tokens of the child's edge label
    # (LoRA children of the root are keyed by node_id — the root is never
    # prefix-matched). Keying by the full first chunk guarantees that any two
    # siblings share < align leading tokens, so radix splits always land on
    # align boundaries and data-plane blocks never straddle nodes.
    children: dict[object, "Node"] = dataclasses.field(default_factory=dict)
    # --- statistics for the cost model -------------------------------------
    visit_count: float = 0.0  # exponentially-decayed visit counter
    last_access: float = 0.0  # LRU time
    last_decay: float = 0.0  # bookkeeping for the decayed counter
    size_bytes: int = 0
    num_blocks: int = 0
    # --- data plane --------------------------------------------------------
    hbm_blocks: list[int] = dataclasses.field(default_factory=list)
    host_blocks: list[int] = dataclasses.field(default_factory=list)
    ref_count: int = 0  # pinned by running queries; cannot be swapped out

    # ------------------------------------------------------------------ util
    @property
    def num_tokens(self) -> int:
        return len(self.tokens)

    @property
    def is_shared(self) -> bool:
        """Whether this is a shared base-model trunk node: adapter-independent
        KV cached once under the root and forked per adapter below."""
        return self.kind is NodeKind.KV and self.lora_id is None

    @property
    def is_leaf(self) -> bool:
        return not self.children

    @property
    def has_payload(self) -> bool:
        """Whether this node owns data-plane blocks in some tier. False for
        hollow STATE interiors created by radix splits (and for dropped
        nodes), which are structure only."""
        return bool(self.hbm_blocks or self.host_blocks)

    def hbm_children(self) -> list["Node"]:
        return [c for c in self.children.values() if c.tier is Residency.HBM]

    def is_hbm_leaf(self) -> bool:
        """Swap-out candidate: resident, unpinned, no HBM-resident child."""
        return (
            self.tier is Residency.HBM
            and self.ref_count == 0
            and not self.hbm_children()
            and self.kind is not NodeKind.ROOT
        )

    def is_host_root(self) -> bool:
        """Swap-in candidate: in host memory with an HBM-resident parent."""
        if self.tier is not Residency.HOST:
            return False
        p = self.parent
        return p is not None and (p.kind is NodeKind.ROOT or p.tier is Residency.HBM)

    def path_tokens(self) -> TokenSeq:
        """Full token prefix from the LoRA node down to (and incl.) this node."""
        parts: list[TokenSeq] = []
        n: Optional[Node] = self
        while n is not None and n.kind in (NodeKind.KV, NodeKind.STATE):
            parts.append(n.tokens)
            n = n.parent
        return tuple(t for seg in reversed(parts) for t in seg)

    def path_num_tokens(self) -> int:
        """Length of :meth:`path_tokens` without materializing the tuple —
        scorers call this per candidate per eviction-loop iteration."""
        out = 0
        n: Optional[Node] = self
        while n is not None and n.kind in (NodeKind.KV, NodeKind.STATE):
            out += len(n.tokens)
            n = n.parent
        return out

    # -------------------------------------------------------------- counters
    def touch(self, now: float, decay_tau: float) -> None:
        """Record a visit at time ``now`` with exponential frequency decay."""
        if decay_tau > 0 and self.last_decay < now:
            self.visit_count *= math.exp(-(now - self.last_decay) / decay_tau)
        self.visit_count += 1.0
        self.last_decay = now
        self.last_access = now

    def decayed_visits(self, now: float, decay_tau: float) -> float:
        if decay_tau <= 0 or now <= self.last_decay:
            return self.visit_count
        return self.visit_count * math.exp(-(now - self.last_decay) / decay_tau)


@dataclasses.dataclass
class MatchResult:
    """Result of prefix-matching a query against the tree."""

    lora_node: Optional[Node]
    kv_nodes: list[Node]  # matched prefix chain, shallow → deep
    matched_tokens: int  # total tokens covered by kv_nodes
    last_node: Node  # deepest matched node (LoRA node if no KV matched;
    # the root when a shared span was declared but no trunk node matched)
    shared_matched_tokens: int = 0  # leading tokens served by the shared trunk

    @property
    def hbm_hit_tokens(self) -> int:
        return sum(n.num_tokens for n in self.kv_nodes if n.tier is Residency.HBM)

    @property
    def host_hit_tokens(self) -> int:
        return sum(n.num_tokens for n in self.kv_nodes if n.tier is Residency.HOST)

    @property
    def shared_hbm_hit_tokens(self) -> int:
        return sum(n.num_tokens for n in self.kv_nodes
                   if n.is_shared and n.tier is Residency.HBM)


class DependencyTree:
    """The unified usage-dependency tree over LoRAs and KV prefixes."""

    def __init__(self, align: int = 1, decay_tau: float = 60.0,
                 block_tokens: int = 0):
        if align < 1:
            raise ValueError("align must be >= 1")
        self.align = align
        # data-plane block quantum for KV block-ownership math. Historically
        # equal to ``align``, but a state-caching tree runs align=1 (snapshot
        # boundaries are arbitrary) while KV blocks are still block_tokens
        # wide — splitting block lists at token offsets would hand a 3-token
        # upper node 3 whole blocks. Ownership therefore always splits at
        # block_tokens boundaries (straddling blocks stay with the lower
        # node).
        self.block_tokens = block_tokens or align
        self.decay_tau = decay_tau
        self.root = Node(kind=NodeKind.ROOT, lora_id=None, tokens=(), tier=None)
        self._lora_nodes: dict[str, Node] = {}
        self._total_visits = 0.0
        self._last_visit_decay = 0.0

    # ------------------------------------------------------------- structure
    def lora_node(self, lora_id: str) -> Optional[Node]:
        return self._lora_nodes.get(lora_id)

    def lora_nodes(self) -> list[Node]:
        return list(self._lora_nodes.values())

    def add_lora(
        self,
        lora_id: str,
        size_bytes: int,
        num_blocks: int,
        tier: Residency = Residency.HOST,
        now: float = 0.0,
    ) -> Node:
        """Insert a LoRA node on the second layer (idempotent)."""
        if lora_id in self._lora_nodes:
            return self._lora_nodes[lora_id]
        node = Node(
            kind=NodeKind.LORA,
            lora_id=lora_id,
            tokens=(),
            tier=tier,
            parent=self.root,
            size_bytes=size_bytes,
            num_blocks=num_blocks,
        )
        node.last_access = now
        node.last_decay = now
        # LoRA children are keyed by id hash in the root's child map; the root
        # is never prefix-matched so any unique key works.
        self.root.children[node.node_id] = node
        self._lora_nodes[lora_id] = node
        return node

    def _child_key(self, parent: Node, lora_id: Optional[str],
                   tokens: TokenSeq) -> object:
        """Child-map key for an edge starting with ``tokens`` under
        ``parent``. Plain first-chunk keys everywhere except the fork point:
        an adapter-labelled child of a shared trunk node (or of the root) is
        keyed ``(lora_id, chunk)`` so forks under different adapters with
        identical divergence tokens coexist as siblings."""
        chunk = tuple(tokens[: self.align])
        if (lora_id is not None and parent.kind is not NodeKind.LORA
                and parent.lora_id is None):
            return (lora_id, chunk)
        return chunk

    def match(self, lora_id: str, tokens: Sequence[Token], now: float,
              shared_len: int = 0) -> MatchResult:
        """DFS prefix match: shared trunk first (when the request declares a
        ``shared_len`` adapter-independent prefix), then the adapter fork.

        With ``shared_len=0`` this is the legacy walk — LoRA node first, then
        the longest KV prefix chain under it. With ``shared_len>0`` the first
        ``shared_len`` (align-quantized) tokens are matched against the
        ``lora_id=None`` trunk under the root; only if the trunk fully covers
        the declared span does the walk cross into this adapter's fork (via
        the composite child key) and continue on plain keys below.

        Only counts a node as matched if the query's remaining tokens fully
        cover the node's edge label (partial edge coverage stops the walk; the
        manager may later split the edge on insert). Match length is quantized
        down to ``align``. Visit counters of matched nodes are updated.
        """
        self._bump_total(now)
        lnode = self._lora_nodes.get(lora_id)
        if lnode is not None:
            lnode.touch(now, self.decay_tau)
        toks = tuple(tokens)
        # quantize usable prefix down to align so data-plane blocks stay whole
        usable = (len(toks) // self.align) * self.align
        toks = toks[:usable]
        shared_usable = (min(max(shared_len, 0), len(toks)) // self.align
                         ) * self.align
        chain: list[Node] = []
        pos = 0
        if shared_usable:
            cur: Node = self.root
            while pos < shared_usable:
                child = cur.children.get(toks[pos : pos + self.align])
                if child is None:
                    break
                # never match a trunk edge past the declared shared span: the
                # remainder of the prompt is adapter-divergent even if its
                # tokens happen to coincide with a longer trunk edge
                common = _common_prefix_len(child.tokens, toks[pos:shared_usable])
                common = (common // self.align) * self.align
                if common == 0:
                    break
                if common < len(child.tokens):
                    child = self._split(child, common)
                child.touch(now, self.decay_tau)
                chain.append(child)
                pos += common
                cur = child
        shared_matched = pos
        if lnode is None:
            return MatchResult(None, chain, pos,
                               chain[-1] if chain else self.root,
                               shared_matched_tokens=shared_matched)
        if shared_usable:
            # adapter fork hangs off the deepest trunk node (or the root when
            # nothing shared is cached yet); reachable only once the trunk
            # covered the whole declared span
            cur = chain[-1] if chain else self.root
            walk = pos == shared_usable
        else:
            cur = lnode
            walk = True
        while walk and pos < len(toks):
            child = cur.children.get(self._child_key(cur, lora_id, toks[pos:]))
            if child is None:
                break
            common = _common_prefix_len(child.tokens, toks[pos:])
            common = (common // self.align) * self.align
            if common == 0:
                break
            if common < len(child.tokens):
                # partial edge coverage: split radix-style so the shared
                # (align-quantized) prefix becomes matchable (SGLang-like).
                child = self._split(child, common)
            child.touch(now, self.decay_tau)
            chain.append(child)
            pos += common
            cur = child
        if chain:
            last = chain[-1]
        else:
            last = self.root if shared_usable else lnode
        return MatchResult(lnode, chain, pos, last,
                           shared_matched_tokens=shared_matched)

    def probe_chain(self, lora_id: str, tokens: Sequence[Token],
                    shared_len: int = 0) -> list[tuple[Node, int]]:
        """READ-ONLY prefix-match estimate: (node, covered tokens) pairs.

        Mirrors :meth:`match`'s trunk-then-fork walk but never touches visit
        counters, never bumps the decayed total, and never splits a
        partially-covered edge — the deadline-aware admission order probes
        every waiting request every step, and a mutating probe would skew the
        cost model's visit-frequency statistics (and restructure the radix
        tree) in proportion to queue depth. The walk stops at the first
        partially-covered edge after counting its align-quantized common
        prefix, which is exactly where a real match would also stop after its
        split — so the covered-token total matches what admission will see
        (modulo forks hanging below a would-be split point: a rare, strict
        underestimate, acceptable for a cost estimate).
        """
        toks = tuple(tokens)
        usable = (len(toks) // self.align) * self.align
        toks = toks[:usable]
        shared_usable = (min(max(shared_len, 0), len(toks)) // self.align
                         ) * self.align
        out: list[tuple[Node, int]] = []
        pos = 0
        cur: Node = self.root
        if shared_usable:
            while pos < shared_usable:
                child = cur.children.get(toks[pos : pos + self.align])
                if child is None:
                    break
                common = _common_prefix_len(child.tokens, toks[pos:shared_usable])
                common = (common // self.align) * self.align
                if common == 0:
                    break
                out.append((child, common))
                pos += common
                if common < len(child.tokens):
                    return out  # partial edge: a real match stops here too
                cur = child
        lnode = self._lora_nodes.get(lora_id)
        if lnode is None:
            return out
        if shared_usable:
            if pos != shared_usable:
                return out  # trunk didn't cover the span: no fork walk
        else:
            cur = lnode
        while pos < len(toks):
            child = cur.children.get(self._child_key(cur, lora_id, toks[pos:]))
            if child is None:
                break
            common = _common_prefix_len(child.tokens, toks[pos:])
            common = (common // self.align) * self.align
            if common == 0:
                break
            out.append((child, common))
            pos += common
            if common < len(child.tokens):
                break
            cur = child
        return out

    def insert_kv(
        self,
        parent: Node,
        tokens: Sequence[Token],
        size_bytes: int,
        num_blocks: int,
        tier: Residency,
        now: float,
    ) -> Node:
        """Insert a KV node under ``parent`` (a LoRA or KV node).

        ``tokens`` is the *suffix* below the parent's path; with align>1 its
        length must be a multiple of ``align``. If the suffix partially
        overlaps an existing child edge, the edge is split radix-style at the
        divergence point (always align-quantized by construction — see the
        children-keying comment on :class:`Node`); sizes divide
        proportionally and the absorbed prefix reuses the existing node.
        Returns the deepest node covering the suffix. Callers needing to know
        how many leading tokens were absorbed by existing nodes should use
        :meth:`insert_kv_ext`.
        """
        node, _ = self.insert_kv_ext(parent, tokens, size_bytes, num_blocks, tier, now)
        return node

    def insert_kv_ext(
        self,
        parent: Node,
        tokens: Sequence[Token],
        size_bytes: int,
        num_blocks: int,
        tier: Residency,
        now: float,
        kind: NodeKind = NodeKind.KV,
        lora_id: object = _INHERIT,
    ) -> tuple[Node, int]:
        """Like :meth:`insert_kv` but also returns the number of leading
        suffix tokens absorbed by pre-existing/split nodes (their data-plane
        blocks are redundant and should be freed by the caller).

        ``kind=NodeKind.STATE`` inserts a state-snapshot boundary instead of
        a KV prefix: callers insert the node as a hollow husk
        (``size_bytes=0, num_blocks=0``) and attach the indivisible snapshot
        payload to the *returned* node after allocating its blocks — the
        per-token proportional size split below is meaningless for a
        fixed-size snapshot.

        ``lora_id`` defaults to inheriting the parent's label. Pass ``None``
        explicitly to grow the shared base-model trunk (parent must be the
        root or another trunk node), or an adapter id to fork an
        adapter-divergent branch off a trunk node."""
        toks = tuple(tokens)
        if not toks:
            raise ValueError("cannot insert empty KV edge")
        if self.align > 1 and len(toks) % self.align != 0:
            raise ValueError(
                f"edge length {len(toks)} not a multiple of align={self.align}"
            )
        if lora_id is _INHERIT:
            lora_id = parent.lora_id
        if parent.kind is NodeKind.ROOT and lora_id is not None:
            raise ValueError(
                "adapter-labelled KV must live under a LoRA or shared branch")
        if lora_id is None and kind is not NodeKind.KV:
            raise ValueError("shared trunk nodes must be KV kind")
        if kind is NodeKind.STATE and parent.lora_id is None:
            # a snapshot is the full model state INCLUDING the adapter's
            # contribution, so it is never adapter-independent
            raise ValueError("STATE snapshots cannot fork off the shared trunk")
        bytes_per_token = size_bytes / len(toks)
        absorbed = 0
        while True:
            existing = parent.children.get(self._child_key(parent, lora_id, toks))
            if existing is None:
                node = Node(
                    kind=kind,
                    lora_id=lora_id,
                    tokens=toks,
                    tier=tier,
                    parent=parent,
                    size_bytes=int(round(bytes_per_token * len(toks))),
                    num_blocks=num_blocks,
                )
                # creation counts as a visit: a freshly committed node is the
                # most-recent state of a live conversation — without this the
                # cost model (prob=0) would evict exactly the nodes most
                # likely to be re-hit on the next turn.
                node.touch(now, self.decay_tau)
                parent.children[self._child_key(parent, lora_id, toks)] = node
                return node, absorbed
            common = _common_prefix_len(existing.tokens, toks)
            common = (common // self.align) * self.align
            if common < self.align:
                raise PoolInvariantError("sibling key collision without overlap")
            if common < len(existing.tokens):
                existing = self._split(existing, common)
            existing.touch(now, self.decay_tau)
            if common == len(toks):
                return existing, absorbed + common  # fully absorbed
            parent = existing
            toks = toks[common:]
            absorbed += common
            num_blocks = max(0, num_blocks - common // self.block_tokens)

    def _split(self, node: Node, at: int) -> Node:
        """Split ``node``'s edge at token offset ``at``; returns the new upper
        node. Stats are copied; sizes divide proportionally (block counts are
        re-derived by the manager for data-plane nodes).

        STATE nodes split *hollow*: a snapshot is the full model state at the
        node's own boundary, so there is no data for the intermediate
        boundary — the upper node gets zero bytes/blocks (pure trie
        structure) and the payload stays whole on the lower node."""
        if not 0 < at < len(node.tokens):
            raise PoolInvariantError(
                f"split offset {at} outside edge of node {node.node_id} "
                f"({len(node.tokens)} tokens)"
            )
        upper_tokens, lower_tokens = node.tokens[:at], node.tokens[at:]
        frac = 0.0 if node.kind is NodeKind.STATE else at / len(node.tokens)
        upper = Node(
            kind=node.kind,
            lora_id=node.lora_id,
            tokens=upper_tokens,
            tier=node.tier,
            parent=node.parent,
            size_bytes=int(node.size_bytes * frac),
            num_blocks=0,
            visit_count=node.visit_count,
            last_access=node.last_access,
            last_decay=node.last_decay,
        )
        if node.parent is None:
            raise PoolInvariantError(
                f"cannot split detached node {node.node_id} (no parent)"
            )
        # a fork root keeps its composite (lora_id, chunk) key in the shared
        # parent's child map; the lower half re-keys plainly under the upper
        node.parent.children[
            self._child_key(node.parent, node.lora_id, upper_tokens)] = upper
        node.parent = upper
        node.tokens = lower_tokens
        node.size_bytes -= upper.size_bytes
        upper.children[self._child_key(upper, node.lora_id, lower_tokens)] = node
        # split block ownership at the aligned boundary (KV only: a state
        # snapshot is indivisible and stays entirely on the lower node)
        if node.kind is not NodeKind.STATE and (node.hbm_blocks or node.host_blocks):
            nb_upper = at // self.block_tokens
            for attr in ("hbm_blocks", "host_blocks"):
                blocks = getattr(node, attr)
                if blocks:
                    setattr(upper, attr, blocks[:nb_upper])
                    setattr(node, attr, blocks[nb_upper:])
            upper.num_blocks = len(upper.hbm_blocks) + len(upper.host_blocks)
            node.num_blocks = len(node.hbm_blocks) + len(node.host_blocks)
        # NOTE: ref_count stays on the lower (original) node only. Pins are
        # held on the *deepest* node of a matched path; ancestors (incl. the
        # new upper) are protected structurally because they have an
        # HBM-resident child and leaf-only eviction never touches them.
        return upper

    def remove(self, node: Node) -> None:
        """Remove a (childless, unpinned) node from the tree."""
        if node.children:
            raise ValueError("cannot remove a node with children")
        if node.ref_count:
            raise ValueError("cannot remove a pinned node")
        parent = node.parent
        if parent is None:
            raise PoolInvariantError(
                f"cannot remove already-detached node {node.node_id}"
            )
        if node.kind is NodeKind.LORA:
            del parent.children[node.node_id]
            del self._lora_nodes[node.lora_id]  # type: ignore[arg-type]
        else:
            del parent.children[self._child_key(parent, node.lora_id, node.tokens)]
        node.parent = None

    # ------------------------------------------------------------ traversals
    def iter_nodes(self, kinds: Optional[set[NodeKind]] = None) -> Iterator[Node]:
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n.kind is NodeKind.ROOT:
                continue
            if kinds is None or n.kind in kinds:
                yield n

    def dependent_fork_loras(self, node: Node) -> set[str]:
        """Adapter ids with fork KV depending on this shared trunk node.

        Walks the subtree below ``node``; descends through deeper trunk
        nodes (their forks depend on this node too) and stops at the first
        adapter-labelled node on each path — everything below it belongs to
        the same adapter. Evicting ``node`` invalidates all of these forks,
        so the cost model prices it by their summed recompute."""
        out: set[str] = set()
        stack = list(node.children.values())
        while stack:
            n = stack.pop()
            if n.lora_id is not None:
                out.add(n.lora_id)
                continue
            stack.extend(n.children.values())
        return out

    def shared_nodes(self) -> list[Node]:
        """All shared base-model trunk nodes (``lora_id=None`` KV)."""
        return [n for n in self.iter_nodes({NodeKind.KV}) if n.is_shared]

    def hbm_leaves(self) -> list[Node]:
        """Swap-out candidates (paper §4.2: evict leaves only)."""
        return [n for n in self.iter_nodes() if n.is_hbm_leaf()]

    def host_roots(self) -> list[Node]:
        """Swap-in candidates (paper §4.2: load subtree roots only)."""
        return [n for n in self.iter_nodes() if n.is_host_root()]

    def hbm_nodes(self) -> list[Node]:
        return [n for n in self.iter_nodes() if n.tier is Residency.HBM]

    def resident_lora_count(self) -> int:
        return sum(
            1 for n in self._lora_nodes.values() if n.tier is Residency.HBM
        )

    # ------------------------------------------------------------ statistics
    def _bump_total(self, now: float) -> None:
        if self.decay_tau > 0 and self._last_visit_decay < now:
            self._total_visits *= math.exp(
                -(now - self._last_visit_decay) / self.decay_tau
            )
        self._total_visits += 1.0
        self._last_visit_decay = now

    def total_visits(self, now: float) -> float:
        if self.decay_tau <= 0 or now <= self._last_visit_decay:
            return self._total_visits
        return self._total_visits * math.exp(
            -(now - self._last_visit_decay) / self.decay_tau
        )

    def visit_prob(self, node: Node, now: float) -> float:
        """prob_i — the node's decayed visit share of all query arrivals."""
        tot = self.total_visits(now)
        if tot <= 0:
            return 0.0
        return min(1.0, node.decayed_visits(now, self.decay_tau) / tot)

    def check_validity_invariant(self) -> None:
        """Every HBM node's parent must be HBM (or the root): no invalid KVs."""
        for n in self.iter_nodes():
            if n.tier is Residency.HBM and n.parent is not None:
                p = n.parent
                if not (p.kind is NodeKind.ROOT or p.tier is Residency.HBM):
                    raise PoolInvariantError(
                        f"validity invariant violated at node {n.node_id} "
                        f"({n.kind}, lora={n.lora_id})"
                    )

    def invalid_hbm_bytes(self) -> int:
        """Bytes of HBM-resident KV whose ancestry is NOT fully resident.

        Always 0 for FastLibra-managed trees; baseline policies (WOM, vLLM)
        report nonzero values here — this reproduces the paper's 46–48 %
        invalid-KV measurements.
        """
        out = 0
        for n in self.iter_nodes({NodeKind.KV, NodeKind.STATE}):
            if n.tier is not Residency.HBM:
                continue
            p = n.parent
            valid = True
            while p is not None and p.kind is not NodeKind.ROOT:
                if p.tier is not Residency.HBM:
                    valid = False
                    break
                p = p.parent
            if not valid:
                out += n.size_bytes
        return out


def _common_prefix_len(a: TokenSeq, b: TokenSeq) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i
