"""Real JAX multi-LoRA serving engine with FASTLIBRA cache management.

Continuous-batching engine that actually executes prefill/decode in JAX on
whatever backend is present (CPU here, TPU in production). The FASTLIBRA
:class:`~repro.core.CacheManager` is the single source of truth for HBM
block allocation; this engine is its data plane:

* matched prefix nodes → ``PagedKVPool.gather`` into the dense running cache,
* newly computed suffixes → ``PagedKVPool.scatter`` into pool blocks at
  commit (paper: "new KVs are retained in HBM directly"),
* swap ops from the performance-driven swapper → physical host↔device copies
  (``PagedKVPool.swap_in/out``) and adapter slot loads (:class:`AdapterStore`),
* dependency-tree bookkeeping (lookup → admit → pin → commit → unpin).

The decode hot loop is one jitted ``model.extend`` over a fixed-slot dense
cache; adapters batch through the SGMV path via per-row ``adapter_ids``.
Prefill runs through the bucketed, jit-cached batch subsystem in
:mod:`repro.serving.prefill`; the exact-shape eager path survives as
``prefill_mode="eager"`` for pinning.

With ``schedule_mode="mixed"`` each engine step is ONE row-masked batched
``extend``: active decode slots ride as 1-token rows next to prefill chunk
rows, packed under a per-step token budget that a latency-servoing
:class:`~repro.serving.scheduler.TokenBudgetController` adapts (Sarathi-
style continuous chunked prefill). ``schedule_mode="alternate"`` keeps the
one-prefill-call-then-one-decode-call step as the ablation pin.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CacheManager, CacheSwapper, NodeKind, SwapKind, make_fastlibra
from ..kvcache import KVPoolSpec, PagedKVPool
from ..lora import AdapterStore
from ..models import build_model
from .metrics import ServingReport, summarize
from .prefill import BatchPrefill, assemble_batch, make_buckets
from .request import Phase, Request
from .scheduler import TokenBudgetController, plan_step


def _default_schedule_mode() -> str:
    # CI's non-blocking sweep flips the default via env without touching
    # every test's EngineConfig construction.
    return os.environ.get("REPRO_SCHEDULE_MODE", "alternate")


@dataclasses.dataclass
class EngineConfig:
    """Engine knobs.

    Scheduling (serving/scheduler.py):

    * ``schedule_mode`` — ``"mixed"`` composes each engine step as ONE
      batched ``extend``: every active decode slot contributes 1 token and
      prefill-phase rows fill the remaining per-step token budget with chunk
      slices (Sarathi-style continuous chunked prefill). ``"alternate"``
      keeps the PR-2 behavior — one bucketed-prefill call then one decode
      call per step — as the ablation pin.
    * ``step_token_budget`` — upper bound on real tokens per mixed step
      (decode tokens + prefill chunk tokens). The scheduling knob that
      replaces the static ``prefill_chunk``, which survives only as the
      per-row chunk ceiling (and keeps ring-window models safe).
    * ``target_step_ms`` — when > 0, a :class:`TokenBudgetController`
      servos the budget against an EMA of measured step wall time so decode
      TPOT stays bounded under prefill load; <= 0 pins the budget static.

    Prefill (serving/prefill.py): ``prefill_mode="bucketed"`` is the
    coalesced, length-bucketed, jit-cached chunked path; ``"eager"`` is the
    exact-shape per-request seed path kept as the correctness pin.
    """

    hbm_bytes: int = 64 << 20  # CPU-test scale; 64 GB on the paper's NPU
    host_bytes: int = 256 << 20
    block_size: int = 16
    max_batch_slots: int = 8
    max_seq_len: int = 256
    variant: str = "fastlibra"  # fastlibra|wom|wos|wol|vllm|slora
    eos_token: int = -1  # -1: run to max_new_tokens
    clock: Callable[[], float] = time.monotonic
    # ---- prefill subsystem (serving/prefill.py)
    # "bucketed": coalesced, length-bucketed, jit-cached chunked prefill;
    # "eager": the exact-shape per-request path (correctness pin / ablation)
    prefill_mode: str = "bucketed"
    prefill_chunk: int = 64  # max suffix tokens fed per engine step & row
    prefill_min_bucket: int = 8  # smallest pad-to bucket (powers of two up)
    # ---- step scheduler (serving/scheduler.py)
    schedule_mode: str = dataclasses.field(
        default_factory=_default_schedule_mode)  # "mixed" | "alternate"
    step_token_budget: int = 128  # max real tokens per mixed step
    target_step_ms: float = 0.0  # >0: budget servos to this step latency


class ServingEngine:
    def __init__(self, model_cfg, config: EngineConfig, key=None):
        self.cfg = config
        self.model_cfg = model_cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        self.model = build_model(model_cfg, dtype=jnp.float32)
        self.params = self.model.init_params(k1)
        if model_cfg.mla is not None:
            # the pool stores the compressed latent + rope key as ONE
            # pseudo-head per token (what _read_dense/_write_dense move),
            # not the expanded num_kv_heads × head_dim layout
            m = model_cfg.mla
            kv_heads, head_dim = 1, m.kv_lora_rank + m.qk_rope_head_dim
        else:
            kv_heads, head_dim = model_cfg.num_kv_heads, model_cfg.resolved_head_dim
        spec = KVPoolSpec(
            num_layers=model_cfg.num_layers,
            block_size=config.block_size,
            kv_heads=kv_heads,
            head_dim=head_dim,
            dtype=jnp.float32,
            use_v=model_cfg.mla is None,
        )
        self.kv_spec = spec
        self.manager, self.swapper = make_fastlibra(
            config.hbm_bytes,
            config.host_bytes,
            kv_bytes_per_token=spec.bytes_per_token,
            block_size=config.block_size,
            variant=config.variant,
        )
        pool_blocks = self.manager.kv_pool.num_hbm_blocks
        host_blocks = self.manager.kv_pool.num_host_blocks
        self.kv_pool = PagedKVPool(spec, pool_blocks, host_blocks)
        self.adapters = AdapterStore(
            self.model, model_cfg.lora.max_adapters, key=k2
        )
        # dense running cache: fixed decode slots
        B, T = config.max_batch_slots, config.max_seq_len
        self.cache = self.model.init_cache(B, T)
        self._slot_req: list[Optional[Request]] = [None] * B
        self._free_slots = deque(range(B))
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode_fn = jax.jit(
            lambda params, lora, cache, tokens, ids: self.model.extend(
                params, cache, tokens, cache["len"], lora=lora, adapter_ids=ids
            )
        )
        chunk = min(config.prefill_chunk, config.max_seq_len)
        if model_cfg.rglru is not None and model_cfg.window_size:
            # ring-indexed window caches: a padded chunk wider than the ring
            # would wrap pad slots onto the chunk's own real writes
            chunk = min(chunk, model_cfg.window_size)
        self.prefill = BatchPrefill(
            self.model, make_buckets(config.prefill_min_bucket, chunk)
        )
        self._prefill_chunk = chunk
        # recurrent layouts (RWKV / RG-LRU hybrid) carry state snapshots, not
        # a per-token dense KV that the paged pool can gather/scatter — they
        # serve with cold prefixes (no history-KV reuse) for now.
        self._kv_reusable = model_cfg.rwkv is None and model_cfg.rglru is None
        self.budget_ctl = TokenBudgetController(
            max_budget=max(config.step_token_budget, B + 1),
            target_step_ms=config.target_step_ms,
            min_budget=B + 1,  # a full decode batch plus 1 prefill token
        )
        self._start_time: Optional[float] = None
        self._epoch = 0.0  # wall baseline for reports; reset_metrics moves it
        # unified mixed-batch token counts (5 s window) — the ONE batch-size
        # signal the swapper/cost model observes (Eq. 3's BS)
        self._batch_tokens: deque[tuple[float, int]] = deque()
        self._step_count = 0
        self._step_ms_sum = 0.0
        self._budget_used = 0
        self._budget_avail = 0

    def reset_metrics(self) -> None:
        """Forget per-request and per-step accounting while keeping jit
        caches, adapters, and FASTLIBRA cache state warm. Benchmarks call
        this after a warm-up trace so one-time XLA compile/autotune costs
        don't pollute the steady-state TTFT/TPOT comparison."""
        from .prefill import PrefillStats

        self.finished.clear()
        self.prefill.stats = PrefillStats()
        self._step_count = 0
        self._step_ms_sum = 0.0
        self._budget_used = 0
        self._budget_avail = 0
        self._batch_tokens.clear()
        self.budget_ctl.ema_ms = 0.0
        self.budget_ctl.steps = 0
        self.budget_ctl._budget = float(self.budget_ctl.max_budget)
        # wall-clock baseline for throughput_qps and fresh hit-rate
        # counters — without these, post-reset reports span the warm-up
        self._epoch = self._now()
        self.manager.stats = type(self.manager.stats)()

    # ----------------------------------------------------------------- LoRA
    def register_adapter(self, adapter_id: str, key=None) -> None:
        key = key if key is not None else jax.random.PRNGKey(hash(adapter_id) % (1 << 30))
        aw = self.adapters.register(adapter_id, key)
        self.manager.register_lora(adapter_id, aw.nbytes, now=self._now())

    # ------------------------------------------------------------- requests
    def submit(self, request: Request) -> None:
        request.submit_time = self._now()
        self.waiting.append(request)

    def _now(self) -> float:
        if self._start_time is None:
            self._start_time = self.cfg.clock()
        return self.cfg.clock() - self._start_time

    # ------------------------------------------------------------ main loop
    def run(self, max_steps: int = 10_000) -> ServingReport:
        """Drive until all submitted requests finish (or step budget)."""
        steps = 0
        while (self.waiting or any(self._slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        wall = self._now() - self._epoch
        return summarize(
            self.finished,
            wall,
            kv_hit_rate=self.manager.stats.kv_hit_rate(),
            lora_hit_rate=self.manager.stats.lora_hit_rate(),
            invalid_kv_fraction=self.manager.invalid_kv_fraction(),
            hbm_utilization=self.manager.hbm_usage(),
            avg_prefill_batch=self.prefill.stats.mean_batch,
            prefill_compiles=self.prefill.compile_count,
            avg_step_ms=self._step_ms_sum / max(1, self._step_count),
            ema_step_ms=self.budget_ctl.ema_ms,
            budget_utilization=(self._budget_used / self._budget_avail
                                if self._budget_avail else 0.0),
        )

    def step(self) -> None:
        now = self._now()
        if self.swapper.due(now):
            self._observe_batch_size(now)
            self.swapper.tick(now)
            self._execute_swaps(self.manager.drain_ops())
        self._admit_waiting()
        t0 = time.perf_counter()
        if self.cfg.schedule_mode == "mixed":
            tokens, planned, budget = self._mixed_step()
        else:
            tokens = self._prefill_once() + self._decode_once()
            planned = budget = 0
        if tokens == 0:
            return  # idle step: nothing dispatched, nothing to observe
        step_ms = (time.perf_counter() - t0) * 1e3
        self.budget_ctl.observe(step_ms)
        self._step_count += 1
        self._step_ms_sum += step_ms
        if budget > 0:
            # utilization counts only tokens packed UNDER the budget —
            # catch-up decode tokens ride outside the plan
            self._budget_used += planned
            self._budget_avail += budget
        self._batch_tokens.append((self._now(), tokens))

    def _mixed_step(self) -> tuple[int, int, int]:
        """One Sarathi-style step: decode slots + budgeted prefill chunks in
        a single row-masked ``extend``.
        Returns (real tokens, budget-planned tokens, budget)."""
        # admission order, not slot order: under a binding budget the
        # planner's waterfill favors earlier rows, so the oldest prefill
        # must come first or slot reuse could starve it
        prefill_rows = sorted(
            (r for r in self._slot_req
             if r is not None and r.phase is Phase.PREFILLING),
            key=lambda r: r.admit_time)
        decode_rows = [r for r in self._slot_req
                       if r is not None and r.phase is Phase.DECODE]
        if not prefill_rows and not decode_rows:
            return 0, 0, 0
        budget = self.budget_ctl.budget
        plan = plan_step(
            [r.slot for r in decode_rows],
            [(r.slot, len(r.prompt) - r.prefill_pos) for r in prefill_rows],
            budget=budget, chunk_ceiling=self._prefill_chunk)
        if not plan.prefill_chunks:
            # pure-decode step: reuse the dedicated S=1 jit instead of
            # padding every decode token to the smallest prefill bucket
            n = self._decode_once()
            return n, n, budget
        transitioned = self._run_chunks(
            {r.slot: r for r in prefill_rows}, plan.prefill_chunks,
            decode_rows)
        # catch-up decode: rows that completed prefill THIS step get their
        # second token from one S=1 dispatch, matching the per-request step
        # cadence of alternate mode (whose separate decode call picks fresh
        # rows up in the same step) — without it every request pays one
        # extra engine step at the prefill→decode transition
        catchup = self._decode_once(transitioned) if transitioned else 0
        return plan.tokens + catchup, plan.tokens, budget

    def _run_chunks(self, by_slot: dict[int, Request],
                    chunks: dict[int, int],
                    decode_rows: list[Request]) -> list[Request]:
        """Assemble and dispatch ONE row-masked batch: per-slot prefill
        chunk slices plus (mixed mode) decode rider rows, then advance
        request state. Shared by the alternate and mixed schedulers so the
        transition bookkeeping cannot diverge between the two modes.
        Returns the rows that completed prefill and entered DECODE."""
        bucket = self.prefill.bucket_for(max(chunks.values()))
        tokens, true_lens, row_mask = assemble_batch(
            self.cfg.max_batch_slots, bucket,
            {s: by_slot[s].prompt[by_slot[s].prefill_pos:
                                  by_slot[s].prefill_pos + c]
             for s, c in chunks.items()},
            {r.slot: r.generated[-1] for r in decode_rows})
        chunk_mask = np.zeros((self.cfg.max_batch_slots,), bool)
        for s in chunks:
            chunk_mask[s] = True
        ids = self._adapter_ids()
        last_logits, new_cache = self.prefill(
            self.params, self.adapters.slots, self.cache,
            jnp.asarray(tokens), jnp.asarray(self.cache["len"]),
            jnp.asarray(true_lens), jnp.asarray(row_mask), ids,
            stat_mask=chunk_mask,
        )
        self.cache = new_cache
        toks = np.asarray(jnp.argmax(last_logits, axis=-1))
        for r in decode_rows:
            r.generated.append(int(toks[r.slot]))
            self._maybe_finish(r)
        transitioned = []
        for s, c in chunks.items():
            r = by_slot[s]
            r.prefill_pos += c
            r.prefill_chunks += 1
            if r.prefill_pos >= len(r.prompt):
                r.phase = Phase.DECODE
                r.generated.append(int(toks[r.slot]))
                r.first_token_time = self._now()
                self._maybe_finish(r)
                if r.phase is Phase.DECODE:
                    transitioned.append(r)
        return transitioned

    # ---------------------------------------------------------------- admit
    def _admit_waiting(self) -> None:
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            now = self._now()
            # match against prompt[:-1]: the last token is always recomputed
            # so prefill yields logits for it (vLLM semantics). Recurrent
            # layouts look up an empty history (cold prefix, LoRA still
            # tracked) — their state is not pool-gatherable.
            history = req.prompt[:-1] if self._kv_reusable else ()
            lk = self.manager.lookup(req.adapter_id, history, now)
            adm = self.manager.admit(lk, now)
            if adm.queued:
                self._execute_swaps(self.manager.drain_ops())
                break  # HBM saturated; retry next step
            suffix_len = len(req.prompt) - lk.match.matched_tokens
            total_new = suffix_len + req.max_new_tokens
            blocks = self.manager.allocate_running(req.request_id, total_new, now)
            if blocks is None:
                self.manager.unpin(adm.pinned)
                self._execute_swaps(self.manager.drain_ops())
                break
            t0 = self._now()
            # drained ops include demand evictions that freed this query's
            # blocks — execute them before touching the pool physically
            self._execute_swaps(self.manager.drain_ops(), req=req)
            self.waiting.popleft()
            req.lookup = lk
            req.pinned = adm.pinned
            req.matched_tokens = lk.match.matched_tokens
            req.hbm_hit_tokens = lk.hbm_hit_tokens
            req.admit_time = t0
            req.slot = self._free_slots.popleft()
            self._slot_req[req.slot] = req
            self._begin_prefill(req)

    def _begin_prefill(self, req: Request) -> None:
        """Gather the matched prefix into the slot's dense cache rows and
        stage the suffix for prefill. In bucketed mode the suffix is consumed
        chunk-by-chunk by :meth:`_prefill_once` (coalesced across requests);
        eager mode runs the whole suffix immediately at its exact shape."""
        slot = req.slot
        m = req.lookup.match
        prefix_len = m.matched_tokens
        # load matched prefix KV from pool blocks into the dense cache
        if prefix_len > 0:
            block_ids = [b for n in m.kv_nodes for b in n.hbm_blocks]
            k, v = self.kv_pool.gather(block_ids)
            self._write_dense(slot, 0, k, v)
        # ensure adapter slot present
        aid = self.adapters.slot_of(req.adapter_id)
        if aid is None:
            aid = self.adapters.load(req.adapter_id)
        self._set_len(slot, prefix_len)
        req.prefill_pos = prefix_len
        if self.cfg.prefill_mode == "eager":
            self._prefill_eager(req)
        else:
            req.phase = Phase.PREFILLING

    def _prefill_eager(self, req: Request) -> None:
        """Seed path: one exact-shape ``model.extend`` over the full suffix
        (one XLA compile per distinct suffix length). Kept as the
        correctness pin and ablation baseline for the bucketed subsystem."""
        slot = req.slot
        prefix_len = req.prefill_pos
        suffix = jnp.asarray(req.prompt[prefix_len:], jnp.int32)[None, :]
        start = jnp.asarray(self.cache["len"])
        ids = self._adapter_ids()
        single = {k: v for k, v in self.cache.items()}
        logits, new_cache = self.model.extend(
            self.params, single, self._pad_rows(suffix, slot),
            start, lora=self.adapters.slots, adapter_ids=ids,
        )
        # only this slot's rows advanced meaningfully; fix other rows' len
        self._merge_cache(new_cache, rows=[slot])
        req.prefill_pos = len(req.prompt)
        req.phase = Phase.DECODE
        tok = int(jnp.argmax(logits[slot, -1]))
        req.generated.append(tok)
        req.first_token_time = self._now()
        self._maybe_finish(req)

    def _prefill_once(self) -> int:
        """One coalesced, bucketed prefill chunk for every PREFILLING row.

        All rows admitted (or still mid-prompt) this step share a single
        jitted ``extend`` padded to the smallest bucket covering the largest
        pending chunk; per-row ``adapter_ids`` batch heterogeneous LoRAs via
        SGMV. Long prompts advance ``prefill_chunk`` tokens per step and
        yield to :meth:`_decode_once` in between (chunked prefill).
        Returns the number of real suffix tokens processed."""
        rows = [r for r in self._slot_req
                if r is not None and r.phase is Phase.PREFILLING]
        if not rows:
            return 0
        chunks = {r.slot: min(len(r.prompt) - r.prefill_pos, self._prefill_chunk)
                  for r in rows}
        self._run_chunks({r.slot: r for r in rows}, chunks, [])
        return sum(chunks.values())

    def _pad_rows(self, row_tokens: jax.Array, slot: int) -> jax.Array:
        """Broadcast a single request's tokens into a full-slot batch."""
        B = self.cfg.max_batch_slots
        S = row_tokens.shape[1]
        out = jnp.zeros((B, S), jnp.int32)
        return out.at[slot].set(row_tokens[0])

    # --------------------------------------------------------------- decode
    def _decode_once(self, rows: Optional[list[Request]] = None) -> int:
        """One-token decode for every DECODE row (or just ``rows``);
        returns the number of tokens generated."""
        active = (rows if rows is not None else
                  [r for r in self._slot_req
                   if r is not None and r.phase is Phase.DECODE])
        if not active:
            return 0
        B = self.cfg.max_batch_slots
        tokens = np.zeros((B, 1), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
        ids = self._adapter_ids()
        logits, new_cache = self._decode_fn(
            self.params, self.adapters.slots, self.cache,
            jnp.asarray(tokens), ids,
        )
        self._merge_cache(new_cache, rows=[r.slot for r in active])
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for r in active:
            r.generated.append(int(toks[r.slot]))
            self._maybe_finish(r)
        return len(active)

    def _maybe_finish(self, req: Request) -> None:
        done = len(req.generated) >= req.max_new_tokens
        if self.cfg.eos_token >= 0 and req.generated[-1] == self.cfg.eos_token:
            done = True
        if not done:
            return
        now = self._now()
        req.finish_time = now
        req.phase = Phase.FINISHED
        self._commit(req, now)
        self._slot_req[req.slot] = None
        self._free_slots.append(req.slot)
        self.finished.append(req)

    def _commit(self, req: Request, now: float) -> None:
        """Scatter the request's new KV into its running blocks and fold them
        into the dependency tree."""
        if not self._kv_reusable:
            # recurrent state is not per-token pool KV: release the running
            # blocks instead of folding unmatchable history into the tree
            self.manager.abort_running(req.request_id)
            self.manager.unpin(req.pinned)
            return
        m = req.lookup.match
        prefix = m.matched_tokens
        full = req.full_tokens
        bs = self.cfg.block_size
        suffix_total = len(full) - prefix
        cache_tokens = (suffix_total // bs) * bs
        if cache_tokens > 0 and self.manager.config.reuse_history_kv:
            blocks = self.manager.running_blocks(req.request_id)
            keep = blocks[: cache_tokens // bs]
            k, v = self._read_dense(req.slot, prefix, prefix + cache_tokens)
            self.kv_pool.scatter(keep, k, v)
        self.manager.commit(req.request_id, req.lookup, full, now)
        self.manager.unpin(req.pinned)

    # ------------------------------------------------------------ swaps
    def _execute_swaps(self, ops, req: Optional[Request] = None) -> None:
        for op in ops:
            t0 = self._now()
            if op.node_kind is NodeKind.LORA:
                if op.kind is SwapKind.SWAP_IN:
                    self.adapters.load(op.lora_id)
                elif op.kind in (SwapKind.SWAP_OUT, SwapKind.DROP):
                    self.adapters.unload(op.lora_id)
                if req is not None and op.kind is SwapKind.SWAP_IN:
                    req.lora_coldstart += self._now() - t0
            else:
                if op.kind is SwapKind.SWAP_IN:
                    self.kv_pool.swap_in(op.src_blocks, op.dst_blocks)
                    if req is not None:
                        req.kv_coldstart += self._now() - t0
                elif op.kind is SwapKind.SWAP_OUT:
                    self.kv_pool.swap_out(op.src_blocks, op.dst_blocks)
                # DROP: nothing physical to do

    # ------------------------------------------------------------- helpers
    def _adapter_ids(self) -> jax.Array:
        """Per-row adapter slots for the SGMV path.

        A request whose adapter was evicted mid-flight must NOT silently run
        through slot 0 (someone else's LoRA): reload it, charging the
        cold-start to the request. Raises if no slot can be freed."""
        ids = np.zeros((self.cfg.max_batch_slots,), np.int32)
        for r in self._slot_req:
            if r is not None:
                s = self.adapters.slot_of(r.adapter_id)
                if s is None:
                    s = self._reload_adapter(r)
                ids[r.slot] = s
        return jnp.asarray(ids)

    def _reload_adapter(self, req: Request) -> int:
        """Reload ``req``'s evicted adapter, evicting an idle resident one
        (not referenced by any active request) if all slots are taken."""
        t0 = self._now()
        try:
            s = self.adapters.load(req.adapter_id)
        except RuntimeError:
            active = {r.adapter_id for r in self._slot_req if r is not None}
            victim = next(
                (a for a in self.adapters.resident if a not in active), None)
            if victim is None:
                raise  # every slot pinned by an in-flight request
            self.adapters.unload(victim)
            s = self.adapters.load(req.adapter_id)
        req.lora_coldstart += self._now() - t0
        return s

    def _set_len(self, slot: int, value: int) -> None:
        self.cache["len"] = self.cache["len"].at[slot].set(value)

    def _merge_cache(self, new_cache: dict, rows: list[int]) -> None:
        """Adopt updated rows from ``new_cache``; keep other rows unchanged.

        Keyed on the cache layout ('len' is (B,), all other leaves are
        layer-stacked (L, B, ...)) rather than guessing the batch axis from
        shapes, which breaks when num_layers == max_batch_slots."""
        B = self.cfg.max_batch_slots
        mask = np.zeros((B,), bool)
        for r in rows:
            mask[r] = True
        sel = jnp.asarray(mask)
        merged = {}
        for key, new in new_cache.items():
            if key == "len":
                m = sel
            else:
                m = sel.reshape((1, B) + (1,) * (new.ndim - 2))
            merged[key] = jnp.where(m, new, self.cache[key])
        self.cache = merged

    def _write_dense(self, slot: int, start: int, k, v) -> None:
        """Place gathered prefix KV (L, T, H, D) into the dense cache rows."""
        T = k.shape[1]
        if self.model_cfg.mla is not None:
            m = self.model_cfg.mla
            latent = k[..., 0, : m.kv_lora_rank]
            krope = k[..., 0, m.kv_lora_rank : m.kv_lora_rank + m.qk_rope_head_dim]
            self.cache["latent"] = jax.lax.dynamic_update_slice(
                self.cache["latent"], latent[:, None].astype(self.cache["latent"].dtype),
                (0, slot, start, 0))
            self.cache["krope"] = jax.lax.dynamic_update_slice(
                self.cache["krope"], krope[:, None].astype(self.cache["krope"].dtype),
                (0, slot, start, 0))
            return
        self.cache["k"] = jax.lax.dynamic_update_slice(
            self.cache["k"], k[:, None].astype(self.cache["k"].dtype),
            (0, slot, start, 0, 0))
        self.cache["v"] = jax.lax.dynamic_update_slice(
            self.cache["v"], v[:, None].astype(self.cache["v"].dtype),
            (0, slot, start, 0, 0))

    def _read_dense(self, slot: int, start: int, end: int):
        """Read dense cache rows back as (L, T, H, D) for pool scatter."""
        if self.model_cfg.mla is not None:
            # pool row == concat(latent, krope): kv_spec.head_dim is
            # constructed as kv_lora_rank + qk_rope_head_dim
            latent = self.cache["latent"][:, slot, start:end]
            krope = self.cache["krope"][:, slot, start:end]
            k = jnp.concatenate([latent, krope], axis=-1)
            return k[:, :, None, :], None
        k = self.cache["k"][:, slot, start:end]
        v = self.cache["v"][:, slot, start:end]
        return k, v

    def _observe_batch_size(self, now: float) -> None:
        """Report the unified mixed-batch token load to the swapper.

        The signal is the per-step REAL token count of the (mixed or
        alternate) batch — decode rows contribute 1 token, prefill rows
        their chunk slice — averaged over the last 5 s. Before the mixed
        scheduler the swapper saw decode-slot occupancy only, blind to the
        prefill share of the batch (Eq. 3's BS under-counted under load)."""
        while self._batch_tokens and self._batch_tokens[0][0] < now - 5.0:
            self._batch_tokens.popleft()
        # an empty window means the engine has been idle for 5 s: observe 0
        # so the demand signal decays instead of freezing at the last busy
        # value (idle steps append nothing to the deque)
        avg = (sum(b for _, b in self._batch_tokens) / len(self._batch_tokens)
               if self._batch_tokens else 0.0)
        self.swapper.observe_batch_size(avg)
