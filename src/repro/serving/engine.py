"""Real JAX multi-LoRA serving engine with FASTLIBRA cache management.

Continuous-batching engine that actually executes prefill/decode in JAX on
whatever backend is present (CPU here, TPU in production). The FASTLIBRA
:class:`~repro.core.CacheManager` is the single source of truth for HBM
block allocation; this engine is its data plane:

* matched prefix nodes → ``PagedKVPool.gather`` into the dense running cache,
* newly computed suffixes → ``PagedKVPool.scatter`` into pool blocks at
  commit (paper: "new KVs are retained in HBM directly"),
* swap ops from the performance-driven swapper → physical host↔device copies
  (``PagedKVPool.swap_in/out``) and adapter slot loads (:class:`AdapterStore`),
* dependency-tree bookkeeping (lookup → admit → pin → commit → unpin).

The decode hot loop is one jitted ``model.extend`` over a fixed-slot dense
cache; adapters batch through the SGMV path via per-row ``adapter_ids``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CacheManager, CacheSwapper, NodeKind, SwapKind, make_fastlibra
from ..kvcache import KVPoolSpec, PagedKVPool
from ..lora import AdapterStore
from ..models import build_model
from .metrics import ServingReport, summarize
from .request import Phase, Request


@dataclasses.dataclass
class EngineConfig:
    hbm_bytes: int = 64 << 20  # CPU-test scale; 64 GB on the paper's NPU
    host_bytes: int = 256 << 20
    block_size: int = 16
    max_batch_slots: int = 8
    max_seq_len: int = 256
    variant: str = "fastlibra"  # fastlibra|wom|wos|wol|vllm|slora
    eos_token: int = -1  # -1: run to max_new_tokens
    clock: Callable[[], float] = time.monotonic


class ServingEngine:
    def __init__(self, model_cfg, config: EngineConfig, key=None):
        self.cfg = config
        self.model_cfg = model_cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        self.model = build_model(model_cfg, dtype=jnp.float32)
        self.params = self.model.init_params(k1)
        spec = KVPoolSpec(
            num_layers=model_cfg.num_layers,
            block_size=config.block_size,
            kv_heads=model_cfg.num_kv_heads,
            head_dim=model_cfg.resolved_head_dim,
            dtype=jnp.float32,
            use_v=model_cfg.mla is None,
        )
        self.kv_spec = spec
        self.manager, self.swapper = make_fastlibra(
            config.hbm_bytes,
            config.host_bytes,
            kv_bytes_per_token=spec.bytes_per_token,
            block_size=config.block_size,
            variant=config.variant,
        )
        pool_blocks = self.manager.kv_pool.num_hbm_blocks
        host_blocks = self.manager.kv_pool.num_host_blocks
        self.kv_pool = PagedKVPool(spec, pool_blocks, host_blocks)
        self.adapters = AdapterStore(
            self.model, model_cfg.lora.max_adapters, key=k2
        )
        # dense running cache: fixed decode slots
        B, T = config.max_batch_slots, config.max_seq_len
        self.cache = self.model.init_cache(B, T)
        self._slot_req: list[Optional[Request]] = [None] * B
        self._free_slots = deque(range(B))
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self._decode_fn = jax.jit(
            lambda params, lora, cache, tokens, ids: self.model.extend(
                params, cache, tokens, cache["len"], lora=lora, adapter_ids=ids
            )
        )
        self._start_time: Optional[float] = None
        self._batch_sizes: deque[tuple[float, int]] = deque()

    # ----------------------------------------------------------------- LoRA
    def register_adapter(self, adapter_id: str, key=None) -> None:
        key = key if key is not None else jax.random.PRNGKey(hash(adapter_id) % (1 << 30))
        aw = self.adapters.register(adapter_id, key)
        self.manager.register_lora(adapter_id, aw.nbytes, now=self._now())

    # ------------------------------------------------------------- requests
    def submit(self, request: Request) -> None:
        request.submit_time = self._now()
        self.waiting.append(request)

    def _now(self) -> float:
        if self._start_time is None:
            self._start_time = self.cfg.clock()
        return self.cfg.clock() - self._start_time

    # ------------------------------------------------------------ main loop
    def run(self, max_steps: int = 10_000) -> ServingReport:
        """Drive until all submitted requests finish (or step budget)."""
        steps = 0
        while (self.waiting or any(self._slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        wall = self._now()
        return summarize(
            self.finished,
            wall,
            kv_hit_rate=self.manager.stats.kv_hit_rate(),
            lora_hit_rate=self.manager.stats.lora_hit_rate(),
            invalid_kv_fraction=self.manager.invalid_kv_fraction(),
            hbm_utilization=self.manager.hbm_usage(),
        )

    def step(self) -> None:
        now = self._now()
        if self.swapper.due(now):
            self._observe_batch_size(now)
            self.swapper.tick(now)
            self._execute_swaps(self.manager.drain_ops())
        self._admit_waiting()
        self._decode_once()

    # ---------------------------------------------------------------- admit
    def _admit_waiting(self) -> None:
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            now = self._now()
            # match against prompt[:-1]: the last token is always recomputed
            # so prefill yields logits for it (vLLM semantics).
            lk = self.manager.lookup(req.adapter_id, req.prompt[:-1], now)
            adm = self.manager.admit(lk, now)
            if adm.queued:
                self._execute_swaps(self.manager.drain_ops())
                break  # HBM saturated; retry next step
            suffix_len = len(req.prompt) - lk.match.matched_tokens
            total_new = suffix_len + req.max_new_tokens
            blocks = self.manager.allocate_running(req.request_id, total_new, now)
            if blocks is None:
                self.manager.unpin(adm.pinned)
                self._execute_swaps(self.manager.drain_ops())
                break
            t0 = self._now()
            # drained ops include demand evictions that freed this query's
            # blocks — execute them before touching the pool physically
            self._execute_swaps(self.manager.drain_ops(), req=req)
            self.waiting.popleft()
            req.lookup = lk
            req.pinned = adm.pinned
            req.matched_tokens = lk.match.matched_tokens
            req.hbm_hit_tokens = lk.hbm_hit_tokens
            req.admit_time = t0
            req.slot = self._free_slots.popleft()
            self._slot_req[req.slot] = req
            self._prefill(req)

    def _prefill(self, req: Request) -> None:
        """Gather matched prefix into the slot's dense cache rows, then run
        the suffix through ``model.extend`` (exact shapes, per request)."""
        slot = req.slot
        m = req.lookup.match
        prefix_len = m.matched_tokens
        # load matched prefix KV from pool blocks into the dense cache
        if prefix_len > 0:
            block_ids = [b for n in m.kv_nodes for b in n.hbm_blocks]
            k, v = self.kv_pool.gather(block_ids)
            self._write_dense(slot, 0, k, v)
        # ensure adapter slot present
        aid = self.adapters.slot_of(req.adapter_id)
        if aid is None:
            aid = self.adapters.load(req.adapter_id)
        suffix = jnp.asarray(req.prompt[prefix_len:], jnp.int32)[None, :]
        self._set_len(slot, prefix_len)
        start = jnp.asarray(self.cache["len"])
        ids = self._adapter_ids()
        single = {k: v for k, v in self.cache.items()}
        logits, new_cache = self.model.extend(
            self.params, single, self._pad_rows(suffix, slot),
            start, lora=self.adapters.slots, adapter_ids=ids,
        )
        # only this slot's rows advanced meaningfully; fix other rows' len
        self._merge_cache(new_cache, rows=[slot])
        req.phase = Phase.DECODE
        tok = int(jnp.argmax(logits[slot, -1]))
        req.generated.append(tok)
        req.first_token_time = self._now()
        self._maybe_finish(req)

    def _pad_rows(self, row_tokens: jax.Array, slot: int) -> jax.Array:
        """Broadcast a single request's tokens into a full-slot batch."""
        B = self.cfg.max_batch_slots
        S = row_tokens.shape[1]
        out = jnp.zeros((B, S), jnp.int32)
        return out.at[slot].set(row_tokens[0])

    # --------------------------------------------------------------- decode
    def _decode_once(self) -> None:
        active = [r for r in self._slot_req if r is not None and r.phase is Phase.DECODE]
        if not active:
            return
        B = self.cfg.max_batch_slots
        tokens = np.zeros((B, 1), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
        ids = self._adapter_ids()
        logits, new_cache = self._decode_fn(
            self.params, self.adapters.slots, self.cache,
            jnp.asarray(tokens), ids,
        )
        self._merge_cache(new_cache, rows=[r.slot for r in active])
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for r in active:
            r.generated.append(int(toks[r.slot]))
            self._maybe_finish(r)

    def _maybe_finish(self, req: Request) -> None:
        done = len(req.generated) >= req.max_new_tokens
        if self.cfg.eos_token >= 0 and req.generated[-1] == self.cfg.eos_token:
            done = True
        if not done:
            return
        now = self._now()
        req.finish_time = now
        req.phase = Phase.FINISHED
        self._commit(req, now)
        self._slot_req[req.slot] = None
        self._free_slots.append(req.slot)
        self.finished.append(req)

    def _commit(self, req: Request, now: float) -> None:
        """Scatter the request's new KV into its running blocks and fold them
        into the dependency tree."""
        m = req.lookup.match
        prefix = m.matched_tokens
        full = req.full_tokens
        bs = self.cfg.block_size
        suffix_total = len(full) - prefix
        cache_tokens = (suffix_total // bs) * bs
        if cache_tokens > 0 and self.manager.config.reuse_history_kv:
            blocks = self.manager.running_blocks(req.request_id)
            keep = blocks[: cache_tokens // bs]
            k, v = self._read_dense(req.slot, prefix, prefix + cache_tokens)
            self.kv_pool.scatter(keep, k, v)
        self.manager.commit(req.request_id, req.lookup, full, now)
        self.manager.unpin(req.pinned)

    # ------------------------------------------------------------ swaps
    def _execute_swaps(self, ops, req: Optional[Request] = None) -> None:
        for op in ops:
            t0 = self._now()
            if op.node_kind is NodeKind.LORA:
                if op.kind is SwapKind.SWAP_IN:
                    self.adapters.load(op.lora_id)
                elif op.kind in (SwapKind.SWAP_OUT, SwapKind.DROP):
                    self.adapters.unload(op.lora_id)
                if req is not None and op.kind is SwapKind.SWAP_IN:
                    req.lora_coldstart += self._now() - t0
            else:
                if op.kind is SwapKind.SWAP_IN:
                    self.kv_pool.swap_in(op.src_blocks, op.dst_blocks)
                    if req is not None:
                        req.kv_coldstart += self._now() - t0
                elif op.kind is SwapKind.SWAP_OUT:
                    self.kv_pool.swap_out(op.src_blocks, op.dst_blocks)
                # DROP: nothing physical to do

    # ------------------------------------------------------------- helpers
    def _adapter_ids(self) -> jax.Array:
        ids = np.zeros((self.cfg.max_batch_slots,), np.int32)
        for r in self._slot_req:
            if r is not None:
                s = self.adapters.slot_of(r.adapter_id)
                ids[r.slot] = s if s is not None else 0
        return jnp.asarray(ids)

    def _set_len(self, slot: int, value: int) -> None:
        self.cache["len"] = self.cache["len"].at[slot].set(value)

    def _merge_cache(self, new_cache: dict, rows: list[int]) -> None:
        """Adopt updated rows from ``new_cache``; keep other rows unchanged."""
        B = self.cfg.max_batch_slots
        mask = np.zeros((B,), bool)
        for r in rows:
            mask[r] = True
        sel = jnp.asarray(mask)

        def pick(new, old):
            if new.ndim == 0:
                return new
            # row axis: 'len' is (B,); layer-stacked arrays are (L, B, ...)
            if new.shape[0] == B and new.ndim >= 1:
                m = sel.reshape((B,) + (1,) * (new.ndim - 1))
            elif new.ndim >= 2 and new.shape[1] == B:
                m = sel.reshape((1, B) + (1,) * (new.ndim - 2))
            else:
                return new
            return jnp.where(m, new, old)

        self.cache = jax.tree.map(pick, new_cache, self.cache)

    def _write_dense(self, slot: int, start: int, k, v) -> None:
        """Place gathered prefix KV (L, T, H, D) into the dense cache rows."""
        T = k.shape[1]
        if self.model_cfg.mla is not None:
            m = self.model_cfg.mla
            latent = k[..., 0, : m.kv_lora_rank]
            krope = k[..., 0, m.kv_lora_rank : m.kv_lora_rank + m.qk_rope_head_dim]
            self.cache["latent"] = jax.lax.dynamic_update_slice(
                self.cache["latent"], latent[:, None].astype(self.cache["latent"].dtype),
                (0, slot, start, 0))
            self.cache["krope"] = jax.lax.dynamic_update_slice(
                self.cache["krope"], krope[:, None].astype(self.cache["krope"].dtype),
                (0, slot, start, 0))
            return
        self.cache["k"] = jax.lax.dynamic_update_slice(
            self.cache["k"], k[:, None].astype(self.cache["k"].dtype),
            (0, slot, start, 0, 0))
        self.cache["v"] = jax.lax.dynamic_update_slice(
            self.cache["v"], v[:, None].astype(self.cache["v"].dtype),
            (0, slot, start, 0, 0))

    def _read_dense(self, slot: int, start: int, end: int):
        """Read dense cache rows back as (L, T, H, D) for pool scatter."""
        if self.model_cfg.mla is not None:
            latent = self.cache["latent"][:, slot, start:end]
            krope = self.cache["krope"][:, slot, start:end]
            m = self.model_cfg.mla
            D = self.kv_spec.head_dim
            k = jnp.concatenate([latent, krope], axis=-1)
            pad = D - k.shape[-1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad)))
            return k[:, :, None, :], None
        k = self.cache["k"][:, slot, start:end]
        v = self.cache["v"][:, slot, start:end]
        return k, v

    def _observe_batch_size(self, now: float) -> None:
        n = sum(1 for r in self._slot_req if r is not None)
        self._batch_sizes.append((now, n))
        while self._batch_sizes and self._batch_sizes[0][0] < now - 5.0:
            self._batch_sizes.popleft()
        if self._batch_sizes:
            avg = sum(b for _, b in self._batch_sizes) / len(self._batch_sizes)
            self.swapper.observe_batch_size(avg)
