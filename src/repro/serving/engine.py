"""Real JAX multi-LoRA serving engine with FASTLIBRA cache management.

Continuous-batching engine that actually executes prefill/decode in JAX on
whatever backend is present (CPU here, TPU in production). The FASTLIBRA
:class:`~repro.core.CacheManager` is the single source of truth for HBM
block allocation; this engine is its data plane:

* matched prefix nodes → ``PagedKVPool.gather`` into the dense running cache,
* newly computed suffixes → ``PagedKVPool.scatter`` into pool blocks at
  commit (paper: "new KVs are retained in HBM directly"),
* recurrent layouts (RWKV / RG-LRU): the prefix cache is the state-snapshot
  subsystem instead — a matched STATE node seeds the slot's recurrent state
  row via ``StateCache.load``/``unflatten_state`` so prefill covers only the
  un-snapshotted suffix, and prefill captures the state at ``len(prompt)-1``
  (chunks are clamped to land on the boundary) for ``commit_state`` to fold
  into the same unified pool,
* swap ops from the performance-driven swapper → physical host↔device copies
  (``PagedKVPool.swap_in/out``) and adapter slot loads (:class:`AdapterStore`),
* dependency-tree bookkeeping (lookup → admit → pin → commit → unpin).

The decode hot loop is one jitted ``model.extend`` over a fixed-slot dense
cache; adapters batch through the SGMV path via per-row ``adapter_ids``.
Prefill runs through the bucketed, jit-cached batch subsystem in
:mod:`repro.serving.prefill`; the exact-shape eager path survives as
``prefill_mode="eager"`` for pinning.

With ``schedule_mode="mixed"`` each engine step is ONE row-masked batched
``extend``: active decode slots ride as 1-token rows next to prefill chunk
rows, packed under a per-step token budget that a latency-servoing
:class:`~repro.serving.scheduler.TokenBudgetController` adapts (Sarathi-
style continuous chunked prefill). ``schedule_mode="alternate"`` keeps the
one-prefill-call-then-one-decode-call step as the ablation pin.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CacheManager, CacheSwapper, NodeKind, Residency, SwapKind, make_fastlibra
from ..obs import (
    ATTRIB_CATEGORIES,
    EV_ABORT,
    EV_ADMIT,
    EV_CALIBRATION,
    EV_DECODE_STEP,
    EV_FINISH,
    EV_PREEMPT,
    EV_PREFILL_CHUNK,
    EV_QUEUE,
    EV_RESUME,
    EV_STEP,
    EV_SUBMIT,
    EV_TTFT_ATTRIBUTION,
    NULL_TRACER,
    TRACK_ENGINE,
    TRACK_QUEUE,
    TRACK_SWAPPER,
    Tracer,
    slot_track,
    trace_env_enabled,
)
from ..kvcache import (
    KVPoolSpec,
    PagedKVPool,
    StateCache,
    StateSpec,
    flat_state_elems,
    flatten_state,
    unflatten_state,
)
from ..lora import AdapterStore
from ..models import build_model
from .metrics import ServingReport, summarize
from .prefill import BatchPrefill, assemble_batch, make_buckets
from .request import PRIORITY_BATCH, Phase, Request
from .scheduler import TokenBudgetController, plan_step


def _default_schedule_mode() -> str:
    # "mixed" is the engine default (the ROADMAP burn-in criterion was met:
    # the CI sweep is stable and now runs blocking); "alternate" survives as
    # the ablation pin. The env override lets CI pin either mode without
    # touching every test's EngineConfig construction.
    return os.environ.get("REPRO_SCHEDULE_MODE", "mixed")


def _default_kernel_backend() -> str:
    # "pallas" makes the Pallas kernels the serving data plane (interpret
    # mode on CPU, Mosaic on TPU); "jnp" is the einsum correctness pin the
    # differential tests compare against. Same env-override pattern as
    # REPRO_SCHEDULE_MODE so CI can pin either backend fleet-wide.
    return os.environ.get("REPRO_KERNEL_BACKEND", "pallas")


@dataclasses.dataclass
class EngineConfig:
    """Engine knobs.

    Scheduling (serving/scheduler.py):

    * ``schedule_mode`` — ``"mixed"`` composes each engine step as ONE
      batched ``extend``: every active decode slot contributes 1 token and
      prefill-phase rows fill the remaining per-step token budget with chunk
      slices (Sarathi-style continuous chunked prefill). ``"alternate"``
      keeps the PR-2 behavior — one bucketed-prefill call then one decode
      call per step — as the ablation pin.
    * ``step_token_budget`` — upper bound on real tokens per mixed step
      (decode tokens + prefill chunk tokens). The scheduling knob that
      replaces the static ``prefill_chunk``, which survives only as the
      per-row chunk ceiling (and keeps ring-window models safe).
    * ``target_step_ms`` — when > 0, a :class:`TokenBudgetController`
      servos the budget against an EMA of measured step wall time so decode
      TPOT stays bounded under prefill load; <= 0 pins the budget static.

    Prefill (serving/prefill.py): ``prefill_mode="bucketed"`` is the
    coalesced, length-bucketed, jit-cached chunked path; ``"eager"`` is the
    exact-shape per-request seed path kept as the correctness pin.
    """

    hbm_bytes: int = 64 << 20  # CPU-test scale; 64 GB on the paper's NPU
    host_bytes: int = 256 << 20
    block_size: int = 16
    max_batch_slots: int = 8
    max_seq_len: int = 256
    variant: str = "fastlibra"  # fastlibra|wom|wos|wol|vllm|slora
    eos_token: int = -1  # -1: run to max_new_tokens
    clock: Callable[[], float] = time.monotonic
    # ---- prefill subsystem (serving/prefill.py)
    # "bucketed": coalesced, length-bucketed, jit-cached chunked prefill;
    # "eager": the exact-shape per-request path (correctness pin / ablation)
    prefill_mode: str = "bucketed"
    prefill_chunk: int = 64  # max suffix tokens fed per engine step & row
    prefill_min_bucket: int = 8  # smallest pad-to bucket (powers of two up)
    # ---- step scheduler (serving/scheduler.py)
    schedule_mode: str = dataclasses.field(
        default_factory=_default_schedule_mode)  # "mixed" | "alternate"
    step_token_budget: int = 128  # max real tokens per mixed step
    target_step_ms: float = 0.0  # >0: budget servos to this step latency
    # ---- kernel data plane (repro.kernels; README.md §Kernels).
    # "pallas": gqa_cached dispatches to the length-trimmed ragged-extend /
    # paged-decode kernels and LoRA projections fuse into fused_sgmv;
    # "jnp": the einsum reference path (correctness pin). Models whose
    # attention sits outside the kernels' contract (windowed/ring, int8-KV,
    # softcap, MLA/recurrent attention math) keep the jnp path either way.
    kernel_backend: str = dataclasses.field(
        default_factory=_default_kernel_backend)  # "pallas" | "jnp"
    # ---- cross-adapter prefix sharing (core/dependency_tree.py trunk).
    # Requests declaring shared_prefix_len > 0 run that span with the
    # adapter INACTIVE (base-model rows) either way; this knob only decides
    # whether the resulting KV is cached once on the shared trunk (True) or
    # per adapter (False — the differential baseline).
    share_prefix_kv: bool = True
    # ---- libra-trace observability (repro.obs; README.md §Observability).
    # True arms the span/audit tracer for this engine; the default follows
    # REPRO_TRACE=1 (same env-override pattern as REPRO_SCHEDULE_MODE).
    # Disabled tracing uses the module no-op singleton: zero events, same
    # compile counts and token streams (the CI overhead gate pins this).
    trace: bool = dataclasses.field(default_factory=trace_env_enabled)
    trace_capacity: int = 200_000  # ring-buffer size before oldest-drop


class ServingEngine:
    def __init__(self, model_cfg, config: EngineConfig, key=None):
        if config.schedule_mode not in ("mixed", "alternate"):
            # step() branches on == "mixed" with a bare else: a typo (or a
            # bad REPRO_SCHEDULE_MODE) must not silently run alternate mode
            raise ValueError(
                f"schedule_mode must be 'mixed' or 'alternate', "
                f"got {config.schedule_mode!r}")
        if config.kernel_backend not in ("jnp", "pallas"):
            raise ValueError(
                f"kernel_backend must be 'jnp' or 'pallas', "
                f"got {config.kernel_backend!r}")
        self.cfg = config
        model_cfg = dataclasses.replace(
            model_cfg, kernel_backend=config.kernel_backend)
        self.model_cfg = model_cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        self.model = build_model(model_cfg, dtype=jnp.float32)
        self.params = self.model.init_params(k1)
        if model_cfg.mla is not None:
            # the pool stores the compressed latent + rope key as ONE
            # pseudo-head per token (what _read_dense/_write_dense move),
            # not the expanded num_kv_heads × head_dim layout
            m = model_cfg.mla
            kv_heads, head_dim = 1, m.kv_lora_rank + m.qk_rope_head_dim
        else:
            kv_heads, head_dim = model_cfg.num_kv_heads, model_cfg.resolved_head_dim
        spec = KVPoolSpec(
            num_layers=model_cfg.num_layers,
            block_size=config.block_size,
            kv_heads=kv_heads,
            head_dim=head_dim,
            dtype=jnp.float32,
            use_v=model_cfg.mla is None,
        )
        self.kv_spec = spec
        # recurrent layouts (RWKV / RG-LRU hybrid) carry fixed-size state
        # snapshots instead of a per-token dense KV: their prefix cache is
        # the state-snapshot subsystem (kvcache/state_cache.py + STATE nodes
        # in the dependency tree), sized from the actual cache row layout
        self._kv_reusable = model_cfg.rwkv is None and model_cfg.rglru is None
        self._state_reusable = not self._kv_reusable
        B, T = config.max_batch_slots, config.max_seq_len
        state_bytes = 0
        if self._state_reusable:
            cache_shapes = jax.eval_shape(lambda: self.model.init_cache(B, T))
            self.state_spec = StateSpec(
                state_elems=flat_state_elems(cache_shapes),
                block_bytes=config.block_size * spec.bytes_per_token,
                dtype=jnp.float32,  # engine cache dtype (widest leaf)
            )
            state_bytes = self.state_spec.snapshot_bytes
        self.tracer = (
            Tracer(capacity=config.trace_capacity) if config.trace else NULL_TRACER
        )
        self.manager, self.swapper = make_fastlibra(
            config.hbm_bytes,
            config.host_bytes,
            kv_bytes_per_token=spec.bytes_per_token,
            block_size=config.block_size,
            variant=config.variant,
            state_bytes=state_bytes,
            share_prefix_kv=config.share_prefix_kv,
            tracer=self.tracer,
        )
        pool_blocks = self.manager.kv_pool.num_hbm_blocks
        host_blocks = self.manager.kv_pool.num_host_blocks
        if self._state_reusable:
            # one data plane per layout: snapshots for recurrent archs (the
            # paged per-token pool would be dead weight)
            self.kv_pool = None
            self.state_cache = StateCache(self.state_spec, pool_blocks, host_blocks)
            # jitted row seed/reset/capture: these sit on every admission's
            # critical path (TTFT), where the eager per-leaf dispatch chain
            # costs more than the snapshot saves at small scales. Shapes are
            # engine constants (blocks_per_snapshot, state_elems), so each
            # compiles exactly once.
            n_elems = self.state_spec.state_elems
            sdtype = self.state_spec.dtype

            def _seed(cache, hbm, blocks, row):
                flat = jnp.take(hbm, blocks, axis=0).reshape(-1)[:n_elems]
                return unflatten_state(cache, row, flat)

            def _reset(cache, row):
                return unflatten_state(
                    cache, row, jnp.zeros((n_elems,), sdtype))

            self._state_seed_fn = jax.jit(_seed)
            self._state_reset_fn = jax.jit(_reset)
            self._state_flatten_fn = jax.jit(
                lambda cache, row: flatten_state(cache, row, dtype=sdtype))
        else:
            self.kv_pool = PagedKVPool(spec, pool_blocks, host_blocks)
            self.state_cache = None
        self.adapters = AdapterStore(
            self.model, model_cfg.lora.max_adapters, key=k2
        )
        # dense running cache: fixed decode slots
        self.cache = self.model.init_cache(B, T)
        self._slot_req: list[Optional[Request]] = [None] * B
        self._free_slots = deque(range(B))
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self.aborted: list[Request] = []
        self._decode_fn = jax.jit(
            lambda params, lora, cache, tokens, ids: self.model.extend(
                params, cache, tokens, cache["len"], lora=lora, adapter_ids=ids
            )
        )
        chunk = min(config.prefill_chunk, config.max_seq_len)
        if model_cfg.rglru is not None and model_cfg.window_size:
            # ring-indexed window caches: a padded chunk wider than the ring
            # would wrap pad slots onto the chunk's own real writes
            chunk = min(chunk, model_cfg.window_size)
        self.prefill = BatchPrefill(
            self.model, make_buckets(config.prefill_min_bucket, chunk)
        )
        self._prefill_chunk = chunk
        self.budget_ctl = TokenBudgetController(
            max_budget=max(config.step_token_budget, B + 1),
            target_step_ms=config.target_step_ms,
            min_budget=B + 1,  # a full decode batch plus 1 prefill token
        )
        self._start_time: Optional[float] = None
        self._epoch = 0.0  # wall baseline for reports; reset_metrics moves it
        # unified mixed-batch token counts (5 s window) — the ONE batch-size
        # signal the swapper/cost model observes (Eq. 3's BS)
        self._batch_tokens: deque[tuple[float, int]] = deque()
        self._step_count = 0
        self._step_ms_sum = 0.0
        self._budget_used = 0
        self._budget_avail = 0

    def reset_metrics(self) -> None:
        """Forget per-request and per-step accounting while keeping jit
        caches, adapters, and FASTLIBRA cache state warm. Benchmarks call
        this after a warm-up trace so one-time XLA compile/autotune costs
        don't pollute the steady-state TTFT/TPOT comparison."""
        from .prefill import PrefillStats

        self.finished.clear()
        self.aborted.clear()
        self.prefill.stats = PrefillStats()
        self._step_count = 0
        self._step_ms_sum = 0.0
        self._budget_used = 0
        self._budget_avail = 0
        self._batch_tokens.clear()
        self.budget_ctl.reset()
        # wall-clock baseline for throughput_qps and fresh hit-rate
        # counters — without these, post-reset reports span the warm-up
        self._epoch = self._now()
        self.manager.stats = type(self.manager.stats)()

    def compile_counts(self) -> dict[str, int]:
        """Distinct compiled programs per jitted entry point (libra-check
        probe). A healthy bucketed engine is bounded by #buckets for prefill
        and 1 per fixed-shape entry point — the compile-count regression
        test pins this so a non-static scalar sneaking into a jit signature
        (one compile per Python value) fails loudly instead of silently
        melting TTFT."""
        from repro.core import jit_cache_size

        counts = {
            "prefill": self.prefill.compile_count,
            "decode": jit_cache_size(self._decode_fn),
        }
        if self.state_cache is not None:
            counts["state"] = (
                jit_cache_size(self._state_seed_fn)
                + jit_cache_size(self._state_reset_fn)
                + jit_cache_size(self._state_flatten_fn)
            )
        return counts

    # ----------------------------------------------------------------- LoRA
    def register_adapter(self, adapter_id: str, key=None) -> None:
        key = key if key is not None else jax.random.PRNGKey(hash(adapter_id) % (1 << 30))
        aw = self.adapters.register(adapter_id, key)
        self.manager.register_lora(adapter_id, aw.nbytes, now=self._now())

    # ------------------------------------------------------------- requests
    def submit(self, request: Request) -> None:
        """Queue a request. A caller-provided ``submit_time`` (trace replay
        with backdated arrivals) is honored; only an unset one is stamped
        with the engine clock — queue/TTFT metrics and the deadline-aware
        admission order all measure against this value."""
        if request.submit_time is None:
            request.submit_time = self._now()
        if request.attrib_cursor is None:
            # TTFT attribution window opens at arrival (request.py)
            request.attrib_cursor = request.submit_time
        if self.tracer.enabled:
            self.tracer.instant(
                TRACK_QUEUE, EV_SUBMIT, request.submit_time,
                rid=request.request_id, adapter=request.adapter_id,
                prompt_tokens=len(request.prompt), priority=request.priority)
        self.waiting.append(request)

    def abort(self, request: Request) -> None:
        """Release everything ``request`` holds — admission pins, running
        blocks, decode slot, staged state — and move it to ``Phase.ABORTED``.
        Safe in any phase; FINISHED/ABORTED requests are left untouched. The
        request keeps whatever tokens it produced but never counts as
        finished; ``run()`` drains leftover in-flight requests through this
        path when its step budget runs out."""
        if request.phase in (Phase.FINISHED, Phase.ABORTED):
            return
        if self.tracer.enabled:
            self.tracer.instant(
                TRACK_QUEUE, EV_ABORT, self._now(),
                rid=request.request_id, phase=request.phase.value)
        if request.phase is Phase.WAITING:
            try:
                self.waiting.remove(request)
            except ValueError:
                pass
        else:
            self.manager.abort_running(request.request_id)
            self.manager.unpin(request.pinned)
            request.pinned = []
            self._execute_swaps(self.manager.drain_ops())
            if request.slot >= 0:
                self._slot_req[request.slot] = None
                self._free_slots.append(request.slot)
                request.slot = -1
            request.staged_state = None
        request.phase = Phase.ABORTED
        request.finish_time = self._now()
        self.aborted.append(request)

    def now(self) -> float:
        """Current engine-clock reading — the time base for ``submit_time``
        backdating and absolute ``deadline`` values."""
        return self._now()

    def export_trace(self, path: str) -> None:
        """Dump the tracer's buffer as Chrome trace-event JSON (loads in
        Perfetto; see repro.obs). A disabled tracer dumps an empty trace."""
        self.tracer.dump(path)

    def _now(self) -> float:
        if self._start_time is None:
            self._start_time = self.cfg.clock()
        return self.cfg.clock() - self._start_time

    # ------------------------------------------------------------ main loop
    def run(self, max_steps: int = 10_000) -> ServingReport:
        """Drive until all submitted requests finish (or step budget).

        Step-budget exhaustion with work still pending is not silent: every
        in-flight request is drained through :meth:`abort` (releasing its
        pins, running blocks, and slot — leaked resources would poison any
        later run on the same engine) and the report carries ``n_unfinished``
        (submitted but not finished at the cut) and ``n_aborted`` instead of
        pretending the trace completed. WAITING requests hold no resources
        and stay queued for a later ``run()``."""
        steps = 0
        while (self.waiting or any(self._slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        unfinished = (len(self.waiting)
                      + sum(1 for r in self._slot_req if r is not None))
        if unfinished:
            for r in list(self._slot_req):
                if r is not None:
                    self.abort(r)
        wall = self._now() - self._epoch
        return summarize(
            self.finished,
            wall,
            n_aborted=len(self.aborted),
            n_unfinished=unfinished,
            n_preempted=self.manager.stats.preemptions,
            kv_hit_rate=self.manager.stats.kv_hit_rate(),
            state_hit_rate=self.manager.stats.state_hit_rate(),
            lora_hit_rate=self.manager.stats.lora_hit_rate(),
            invalid_kv_fraction=self.manager.invalid_kv_fraction(),
            hbm_utilization=self.manager.hbm_usage(),
            avg_prefill_batch=self.prefill.stats.mean_batch,
            prefill_compiles=self.prefill.compile_count,
            avg_step_ms=self._step_ms_sum / max(1, self._step_count),
            ema_step_ms=self.budget_ctl.ema_ms,
            budget_utilization=(self._budget_used / self._budget_avail
                                if self._budget_avail else 0.0),
        )

    def step(self) -> None:
        now = self._now()
        if self.swapper.due(now):
            self._observe_batch_size(now)
            self.swapper.tick(now)
            self._execute_swaps(self.manager.drain_ops())
        self._admit_waiting()
        t0 = time.perf_counter()
        if self.cfg.schedule_mode == "mixed":
            tokens, planned, budget = self._mixed_step()
        else:
            tokens = self._prefill_once() + self._decode_once()
            planned = budget = 0
        if tokens == 0:
            return  # idle step: nothing dispatched, nothing to observe
        step_ms = (time.perf_counter() - t0) * 1e3
        self.budget_ctl.observe(step_ms)
        self._step_count += 1
        self._step_ms_sum += step_ms
        if budget > 0:
            # utilization counts only tokens packed UNDER the budget —
            # catch-up decode tokens ride outside the plan
            self._budget_used += planned
            self._budget_avail += budget
        t_end = self._now()
        if self.tracer.enabled:
            self.tracer.span(
                TRACK_ENGINE, EV_STEP, now, t_end,
                tokens=tokens, planned=planned, budget=budget,
                step_ms=step_ms)
            self.tracer.counter("queue_depth", t_end,
                                waiting=float(len(self.waiting)))
            self.tracer.counter("hbm_usage", t_end,
                                frac=float(self.manager.hbm_usage()))
        self._batch_tokens.append((t_end, tokens))

    def _mixed_step(self) -> tuple[int, int, int]:
        """One Sarathi-style step: decode slots + budgeted prefill chunks in
        a single row-masked ``extend``.
        Returns (real tokens, budget-planned tokens, budget)."""
        # priority tier first, then admission order, not slot order: under a
        # binding budget the planner's waterfill favors earlier rows, so
        # within a tier the oldest prefill must come first or slot reuse
        # could starve it
        prefill_rows = sorted(
            (r for r in self._slot_req
             if r is not None and r.phase is Phase.PREFILLING),
            key=lambda r: (-r.priority, r.admit_time))
        decode_rows = [r for r in self._slot_req
                       if r is not None and r.phase is Phase.DECODE]
        if not prefill_rows and not decode_rows:
            return 0, 0, 0
        budget = self.budget_ctl.budget
        # interactive fast lane: above-batch-tier rows prefill greedily (up
        # to the chunk ceiling) before the leftover budget splits evenly, so
        # an interactive TTFT scales with its own prompt, not the number of
        # batch prefills in flight
        fast = frozenset(r.slot for r in prefill_rows
                         if r.priority > PRIORITY_BATCH)
        plan = plan_step(
            [r.slot for r in decode_rows],
            [(r.slot, len(r.prompt) - r.prefill_pos) for r in prefill_rows],
            budget=budget, chunk_ceiling=self._prefill_chunk,
            fast_slots=fast)
        if not plan.prefill_chunks:
            # pure-decode step: reuse the dedicated S=1 jit instead of
            # padding every decode token to the smallest prefill bucket
            n = self._decode_once()
            return n, n, budget
        by_slot = {r.slot: r for r in prefill_rows}
        chunks = dict(plan.prefill_chunks)
        clipped = self._clamp_state_chunks(chunks, by_slot)
        clipped += self._clamp_shared_chunks(chunks, by_slot)
        transitioned = self._run_chunks(by_slot, chunks, decode_rows)
        # catch-up decode: rows that completed prefill THIS step get their
        # second token from one S=1 dispatch, matching the per-request step
        # cadence of alternate mode (whose separate decode call picks fresh
        # rows up in the same step) — without it every request pays one
        # extra engine step at the prefill→decode transition
        catchup = self._decode_once(transitioned) if transitioned else 0
        tokens = plan.tokens - clipped
        return tokens + catchup, tokens, budget

    def _clamp_state_chunks(self, chunks: dict[int, int],
                            by_slot: dict[int, Request]) -> int:
        """Recurrent layouts: a chunk may not stride across a row's snapshot
        boundary — the state must be observable at exactly
        ``state_capture_at`` for :meth:`_run_chunks` to capture it (the
        recurrence is destructive; an intermediate state cannot be recovered
        later). Shrinks chunks in place; returns the clipped token count."""
        if not self._state_reusable:
            return 0
        clipped = 0
        for s, c in list(chunks.items()):
            r = by_slot[s]
            q = r.state_capture_at
            if r.staged_state is None and r.prefill_pos < q < r.prefill_pos + c:
                chunks[s] = q - r.prefill_pos
                clipped += c - chunks[s]
        return clipped

    def _shared_bound(self, req: Request) -> int:
        """Absolute prompt position where ``req``'s declared adapter-
        independent span ends (0 = none)."""
        return min(max(req.shared_prefix_len, 0), len(req.prompt))

    def _clamp_shared_chunks(self, chunks: dict[int, int],
                             by_slot: dict[int, Request]) -> int:
        """A chunk may not straddle a row's shared-prefix boundary: the SGMV
        adapter id is per ROW per dispatch, so base-model tokens (inside the
        declared shared span) and adapter tokens cannot share one chunk.
        Shrinks chunks in place; returns the clipped token count."""
        clipped = 0
        for s, c in list(chunks.items()):
            r = by_slot[s]
            b = self._shared_bound(r)
            if r.prefill_pos < b < r.prefill_pos + c:
                chunks[s] = b - r.prefill_pos
                clipped += c - chunks[s]
        return clipped

    def _run_chunks(self, by_slot: dict[int, Request],
                    chunks: dict[int, int],
                    decode_rows: list[Request]) -> list[Request]:
        """Assemble and dispatch ONE row-masked batch: per-slot prefill
        chunk slices plus (mixed mode) decode rider rows, then advance
        request state. Shared by the alternate and mixed schedulers so the
        transition bookkeeping cannot diverge between the two modes.
        Returns the rows that completed prefill and entered DECODE."""
        t_dispatch = self._now()
        bucket = self.prefill.bucket_for(max(chunks.values()))
        tokens, true_lens, row_mask = assemble_batch(
            self.cfg.max_batch_slots, bucket,
            {s: by_slot[s].prompt[by_slot[s].prefill_pos:
                                  by_slot[s].prefill_pos + c]
             for s, c in chunks.items()},
            {r.slot: r.generated[-1] for r in decode_rows})
        chunk_mask = np.zeros((self.cfg.max_batch_slots,), bool)
        for s in chunks:
            chunk_mask[s] = True
        ids = self._adapter_ids()
        # tokens/true_lens/row_mask stay host-side np arrays: BatchPrefill
        # does its stats math on them before dispatch, and wrapping them in
        # jnp.asarray here forced a device round trip per step (jit commits
        # them to device at dispatch either way)
        last_logits, new_cache = self.prefill(
            self.params, self.adapters.slots, self.cache,
            tokens, jnp.asarray(self.cache["len"]),
            true_lens, row_mask, ids,
            stat_mask=chunk_mask,
        )
        self.cache = new_cache
        # sampled tokens must reach Python for generation/finish
        # bookkeeping: ONE batched transfer per step is the right shape
        # libra: ignore[host-sync]
        toks = np.asarray(jnp.argmax(last_logits, axis=-1))
        t_done = self._now()  # post-transfer: the dispatch actually finished
        for r in decode_rows:
            if self.tracer.enabled:
                self.tracer.span(slot_track(r.slot), EV_DECODE_STEP,
                                 t_dispatch, t_done, rid=r.request_id)
            r.generated.append(int(toks[r.slot]))
            self._maybe_finish(r)
        transitioned = []
        for s, c in chunks.items():
            r = by_slot[s]
            # TTFT attribution: [cursor, dispatch) was scheduler wait, the
            # dispatch itself splits recompute/compute by this chunk's share
            # of previously-computed history (preemption/eviction rebuild)
            r.charge("stall", t_dispatch)
            r.charge_prefill(
                t_done, c,
                max(0, min(r.prefill_pos + c, r.recompute_boundary)
                    - r.prefill_pos))
            if self.tracer.enabled:
                self.tracer.span(slot_track(s), EV_PREFILL_CHUNK,
                                 t_dispatch, t_done, rid=r.request_id,
                                 pos=r.prefill_pos, tokens=c)
            r.prefill_pos += c
            r.prefill_chunks += 1
            if (self._state_reusable and r.staged_state is None
                    and r.prefill_pos == r.state_capture_at):
                # the row's recurrence now sits exactly at the snapshot
                # boundary (chunks were clamped to land here): capture the
                # flat state, staged until commit folds it into the pool
                r.staged_state = self._state_flatten_fn(
                    self.cache, jnp.asarray(s, jnp.int32))
            if r.prefill_pos >= len(r.prompt):
                r.phase = Phase.DECODE
                r.generated.append(int(toks[r.slot]))
                if r.first_token_time is None:
                    # a resumed preemption victim keeps its TRUE first-token
                    # time from before the preemption
                    t_ft = self._now()
                    r.charge("compute", t_ft)  # closes the TTFT partition
                    r.first_token_time = t_ft
                self._maybe_finish(r)
                if r.phase is Phase.DECODE:
                    transitioned.append(r)
        return transitioned

    # ---------------------------------------------------------------- admit
    def _admission_rank(self, req: Request, now: float):
        """Admission sort key: priority tier first (higher = earlier), then
        least deadline slack — ``deadline − now − estimated TTFT``, the TTFT
        priced by the cost model's read-only probe (prefix recompute +
        host-KV/state transfer + adapter cold-start), so a request whose
        cached prefix makes it cheap to serve jumps ahead of one that must
        recompute everything — then FCFS on arrival. Requests without a
        deadline rank after deadline-bearing peers of their tier in plain
        arrival order, so a legacy trace (no tiers, no deadlines) admits in
        exactly the old FCFS order."""
        if req.deadline is None:
            slack = float("inf")
        else:
            est = self.manager.estimate_ttft(
                req.adapter_id, req.prompt[:-1],
                shared_prefix_len=req.shared_prefix_len)
            slack = req.deadline - now - est
        sub = req.submit_time if req.submit_time is not None else now
        return (-req.priority, slack, sub, req.request_id)

    def _admit_waiting(self) -> None:
        """Admit waiting requests in cost-ranked order; a request that
        outranks running work may preempt. One admission (or preemption) per
        pass — each changes pool state, so the queue re-ranks in between.
        The head of the *ranked* order gates the queue (no leapfrogging a
        blocked higher-ranked request with the resources it is waiting on);
        when it cannot start and no preemption applies, admission stalls
        until the next step, exactly like the old FCFS head-of-line break."""
        while self.waiting:
            now = self._now()
            head = sorted(self.waiting,
                          key=lambda r: self._admission_rank(r, now))[0]
            if self._free_slots and self._try_admit(head, now):
                continue
            if self._preempt_for(head, now):
                continue
            break

    def _try_admit(self, req: Request, now: float) -> bool:
        """lookup → admit/pin → allocate → slot → begin prefill; False (with
        pins rolled back) when HBM or running-block space is exhausted."""
        # match against prompt[:-1]: the last token is always recomputed
        # so prefill yields logits for it (vLLM semantics). Recurrent
        # layouts match state-snapshot boundaries instead of per-token KV
        # — the resumable prefix is the deepest payload snapshot.
        history = req.prompt[:-1]
        if self.tracer.enabled and req.ttft_predicted is None:
            # calibration series: sample the admission cost model's TTFT
            # estimate ONCE (first admission, pre-lookup so the probe sees
            # the same tree state the ranking did) for predicted-vs-actual
            req.ttft_predicted = self.manager.estimate_ttft(
                req.adapter_id, history,
                shared_prefix_len=req.shared_prefix_len)
        if self._state_reusable:
            lk = self.manager.lookup_state(req.adapter_id, history, now)
            matched = lk.state_tokens
        else:
            lk = self.manager.lookup(
                req.adapter_id, history, now,
                shared_prefix_len=req.shared_prefix_len)
            matched = lk.match.matched_tokens
        adm = self.manager.admit(lk, now)
        if adm.queued:
            self._execute_swaps(self.manager.drain_ops())
            return False  # HBM saturated; retry next step
        if self._state_reusable:
            # recurrent running memory is ONE fixed-size state row, not
            # per-token KV: reserve a single snapshot's blocks as the
            # admission throttle. Per-token phantom blocks would evict
            # real snapshots from the same pool to back bytes that the
            # architecture never allocates.
            total_new = self.manager.config.state_blocks * self.cfg.block_size
        else:
            total_new = len(req.prompt) - matched + req.max_new_tokens
        blocks = self.manager.allocate_running(req.request_id, total_new, now)
        if blocks is None:
            self.manager.unpin(adm.pinned)
            self._execute_swaps(self.manager.drain_ops())
            return False
        t0 = self._now()
        qstart = req.attrib_cursor  # queue-wait start (arrival or requeue)
        req.charge("queue", t0)
        # drained ops include demand evictions that freed this query's
        # blocks — execute them before touching the pool physically
        self._execute_swaps(self.manager.drain_ops(), req=req)
        self.waiting.remove(req)
        req.lookup = lk
        req.pinned = adm.pinned
        req.matched_tokens = matched
        req.hbm_hit_tokens = lk.hbm_hit_tokens
        req.admit_time = t0
        req.slot = self._free_slots.popleft()
        self._slot_req[req.slot] = req
        if self.tracer.enabled:
            if qstart is not None:
                self.tracer.span(TRACK_QUEUE, EV_QUEUE, qstart, t0,
                                 rid=req.request_id)
            self.tracer.instant(
                slot_track(req.slot),
                EV_RESUME if req.preempt_count else EV_ADMIT, t0,
                rid=req.request_id, adapter=req.adapter_id,
                matched=matched, hbm_hit=lk.hbm_hit_tokens)
        self._begin_prefill(req)
        return True

    def _preempt_for(self, req: Request, now: float) -> bool:
        """Preempt ONE running victim of strictly lower priority so ``req``
        can start. Victim choice is deterministic: lowest tier first, then
        no-deadline before farthest deadline, then youngest admission (least
        sunk work lost), then request id. Returns False (no preemption) when
        nothing running ranks strictly below ``req`` — equal-priority work
        is never preempted, so batch-only traffic keeps the old semantics
        and the admit/preempt loop terminates (every preemption removes a
        strictly-lower-priority row)."""
        victims = [r for r in self._slot_req
                   if r is not None and r.priority < req.priority
                   and r.phase in (Phase.PREFILLING, Phase.DECODE)]
        if not victims:
            return False
        victim = min(victims, key=lambda r: (
            r.priority,
            -(r.deadline if r.deadline is not None else float("inf")),
            -(r.admit_time if r.admit_time is not None else 0.0),
            r.request_id,
        ))
        self._preempt(victim, now)
        return True

    def _preempt(self, victim: Request, now: float) -> None:
        """Swap a running victim out through the two-tier pool and requeue
        it for token-identical resume.

        Unlike discard-preemption, the victim's computed work survives: its
        block-aligned computed KV is scattered into its running blocks and
        folded into the dependency tree via :meth:`CacheManager.
        preempt_running` (recurrent layouts fold a state snapshot captured at
        the current recurrence position instead) — ordinary evictable nodes
        the performance-driven swapper demotes to host under pressure. The
        tokens it generated fold into the prompt (kept in ``carried``), so
        the resume lookup matches the demoted prefix exactly: a decode-phase
        victim re-prefills just ONE token (the pending decode input) from
        its swapped KV/state and continues the identical output stream.
        """
        slot = victim.slot
        folded = len(victim.generated)
        # attribution: time since the last charge was spent running/waiting
        # in the slot; the preemption work itself lands in "other" below.
        # Also remember how far this request had computed — the resume
        # prefill below that boundary is "recompute", not fresh compute.
        victim.charge("stall", now)
        computed_upto = (len(victim.prompt) + folded - 1
                         if victim.phase is Phase.DECODE
                         else victim.prefill_pos)
        if self.tracer.enabled:
            self.tracer.instant(slot_track(slot), EV_PREEMPT, now,
                                rid=victim.request_id,
                                phase=victim.phase.value, folded=folded)
        if self._state_reusable:
            # the resumable boundary is wherever the recurrence actually
            # sits: full_tokens[:-1] for a decode row (capture it NOW — the
            # recurrence is destructive), or the already-staged capture
            # boundary mid-prefill; an uncrossed boundary has no snapshot
            # and the victim re-prefills from its admission-time match
            if victim.phase is Phase.DECODE:
                snap = self._state_flatten_fn(
                    self.cache, jnp.asarray(slot, jnp.int32))
                snap_at = len(victim.prompt) + folded - 1
            elif victim.staged_state is not None:
                snap, snap_at = victim.staged_state, victim.state_capture_at
            else:
                snap, snap_at = None, -1
            self.manager.preempt_running(victim.request_id, None, (), now)
            self.manager.unpin(victim.pinned)
            if snap is not None:
                prefix = (victim.prompt + tuple(victim.generated))[:snap_at]
                node = self.manager.commit_state(
                    victim.adapter_id, prefix, now)
                # demand evictions that freed the snapshot's blocks must hit
                # the data plane BEFORE the store overwrites those rows
                self._execute_swaps(self.manager.drain_ops())
                if node is not None:
                    self.state_cache.store(node.hbm_blocks, snap)
            victim.staged_state = None
        else:
            m = victim.lookup.match
            prefix_len = m.matched_tokens
            if victim.phase is Phase.DECODE:
                # cache covers full_tokens[:-1]; generated[-1] is the
                # pending decode input, not yet attended — not committable
                computed = victim.prompt + tuple(victim.generated[:-1])
            else:
                computed = victim.prompt[: victim.prefill_pos]
            bs = self.cfg.block_size
            cache_tokens = ((len(computed) - prefix_len) // bs) * bs
            if cache_tokens > 0 and self.manager.config.reuse_history_kv:
                blocks = self.manager.running_blocks(victim.request_id)
                keep = blocks[: cache_tokens // bs]
                k, v = self._read_dense(
                    slot, prefix_len, prefix_len + cache_tokens)
                self.kv_pool.scatter(keep, k, v)
            self.manager.preempt_running(
                victim.request_id, victim.lookup, computed, now)
            self.manager.unpin(victim.pinned)
            self._execute_swaps(self.manager.drain_ops())
        # requeue: generated tokens fold into the prompt so the resume
        # lookup matches the demoted KV/state; they live on in `carried`
        # and max_new_tokens shrinks by the same count
        if folded:
            victim.prompt = victim.prompt + tuple(victim.generated)
            victim.carried.extend(victim.generated)
            victim.generated = []
            victim.max_new_tokens -= folded
        victim.lookup = None
        victim.pinned = []
        victim.matched_tokens = 0
        victim.hbm_hit_tokens = 0
        victim.prefill_pos = 0
        victim.state_capture_at = -1
        victim.phase = Phase.WAITING
        victim.preempt_count += 1
        victim.recompute_boundary = max(victim.recompute_boundary,
                                        computed_upto)
        victim.charge("other", self._now())  # swap-out/fold bookkeeping
        self._slot_req[slot] = None
        self._free_slots.append(slot)
        victim.slot = -1
        self.waiting.append(victim)

    def _begin_prefill(self, req: Request) -> None:
        """Gather the matched prefix into the slot's dense cache rows and
        stage the suffix for prefill. In bucketed mode the suffix is consumed
        chunk-by-chunk by :meth:`_prefill_once` (coalesced across requests);
        eager mode runs the whole suffix immediately at its exact shape."""
        slot = req.slot
        m = req.lookup.match
        if self._state_reusable:
            prefix_len = self._seed_state_row(req)
        else:
            prefix_len = m.matched_tokens
            # load matched prefix KV from pool blocks into the dense cache
            if prefix_len > 0:
                block_ids = [b for n in m.kv_nodes for b in n.hbm_blocks]
                k, v = self.kv_pool.gather(block_ids)
                self._write_dense(slot, 0, k, v)
        # ensure adapter slot present — unless the request starts inside its
        # shared span: base-model rows need no slot, so a shared-prefix hit
        # lets prefill begin while the adapter is still cold (_adapter_ids
        # lazily reloads once the row crosses the fork boundary)
        if prefix_len >= self._shared_bound(req):
            aid = self.adapters.slot_of(req.adapter_id)
            if aid is None:
                req.charge("other", self._now())
                aid = self.adapters.load(req.adapter_id)
                req.charge("lora_load", self._now())
        self._set_len(slot, prefix_len)
        req.prefill_pos = prefix_len
        req.charge("other", self._now())  # prefix gather/seed bookkeeping
        if self.cfg.prefill_mode == "eager":
            self._prefill_eager(req)
        else:
            req.phase = Phase.PREFILLING

    def _seed_state_row(self, req: Request) -> int:
        """Recurrent layouts: reset the slot's carried state (the dense row
        still holds the previous occupant's recurrence) and, on a snapshot
        hit, seed it from the pool so prefill covers only the un-snapshotted
        suffix. Also decides the capture boundary: ``len(prompt) - 1``, so an
        identical repeat matches the snapshot against ``prompt[:-1]`` and
        still recomputes its last token for first-token logits. Returns the
        resume boundary (0 = cold prefix)."""
        slot = req.slot
        row = jnp.asarray(slot, jnp.int32)
        prefix_len = 0
        snode = req.lookup.state_node
        if (snode is not None and snode.tier is Residency.HBM
                and snode.hbm_blocks):
            # seeding writes every snapshot leaf of the row, so it doubles
            # as the reset of the previous occupant's carried state
            self.cache = self._state_seed_fn(
                self.cache, self.state_cache.hbm,
                jnp.asarray(snode.hbm_blocks, jnp.int32), row)
            prefix_len = req.lookup.state_tokens
        else:
            self.cache = self._state_reset_fn(self.cache, row)
        req.matched_tokens = prefix_len
        q = len(req.prompt) - 1
        req.state_capture_at = q if q > prefix_len else -1
        return prefix_len

    def _prefill_eager(self, req: Request) -> None:
        """Seed path: exact-shape ``model.extend`` over the full suffix (one
        XLA compile per distinct suffix length). Kept as the correctness pin
        and ablation baseline for the bucketed subsystem. Recurrent layouts
        with a pending snapshot boundary run the suffix as two spans split at
        the boundary, capturing the state in between (the recurrence is
        destructive — there is no recovering an interior state afterwards)."""
        slot = req.slot
        pos0 = req.prefill_pos
        t_entry = self._now()
        # span cut points: the snapshot boundary (recurrent layouts) and the
        # shared-prefix boundary (base-model rows cannot share a dispatch
        # with adapter rows — the SGMV id is per row per call)
        cuts = set()
        q = req.state_capture_at
        if (self._state_reusable and req.staged_state is None
                and req.prefill_pos < q):
            cuts.add(q)
        sb = self._shared_bound(req)
        if req.prefill_pos < sb < len(req.prompt):
            cuts.add(sb)
        points = [req.prefill_pos] + sorted(cuts) + [len(req.prompt)]
        spans = list(zip(points, points[1:]))
        logits = None
        for lo, hi in spans:
            suffix = jnp.asarray(req.prompt[lo:hi], jnp.int32)[None, :]
            start = jnp.asarray(self.cache["len"])
            ids = self._adapter_ids(base_rows=(slot,) if hi <= sb else ())
            single = {k: v for k, v in self.cache.items()}
            logits, new_cache = self.model.extend(
                self.params, single, self._pad_rows(suffix, slot),
                start, lora=self.adapters.slots, adapter_ids=ids,
            )
            # only this slot's rows advanced meaningfully; fix other rows' len
            self._merge_cache(new_cache, rows=[slot])
            if hi == q and req.staged_state is None:
                req.staged_state = self._state_flatten_fn(
                    self.cache, jnp.asarray(slot, jnp.int32))
        req.prefill_pos = len(req.prompt)
        req.phase = Phase.DECODE
        # first sampled token must reach Python (eager fallback path,
        # one scalar transfer per admitted request)
        # libra: ignore[host-sync]
        tok = int(jnp.argmax(logits[slot, -1]))
        req.generated.append(tok)
        t_done = self._now()
        if req.first_token_time is None:
            # attribution: the whole eager suffix dispatched in one go —
            # split by its previously-computed share, then close the window
            req.charge_prefill(
                t_done, len(req.prompt) - pos0,
                max(0, min(len(req.prompt), req.recompute_boundary) - pos0))
            req.first_token_time = t_done
        if self.tracer.enabled:
            self.tracer.span(slot_track(slot), EV_PREFILL_CHUNK,
                             t_entry, t_done, rid=req.request_id, pos=pos0,
                             tokens=len(req.prompt) - pos0, eager=True)
        self._maybe_finish(req)

    def _prefill_once(self) -> int:
        """One coalesced, bucketed prefill chunk for every PREFILLING row.

        All rows admitted (or still mid-prompt) this step share a single
        jitted ``extend`` padded to the smallest bucket covering the largest
        pending chunk; per-row ``adapter_ids`` batch heterogeneous LoRAs via
        SGMV. Long prompts advance ``prefill_chunk`` tokens per step and
        yield to :meth:`_decode_once` in between (chunked prefill).
        Returns the number of real suffix tokens processed."""
        rows = [r for r in self._slot_req
                if r is not None and r.phase is Phase.PREFILLING]
        if not rows:
            return 0
        chunks = {r.slot: min(len(r.prompt) - r.prefill_pos, self._prefill_chunk)
                  for r in rows}
        self._clamp_state_chunks(chunks, {r.slot: r for r in rows})
        self._clamp_shared_chunks(chunks, {r.slot: r for r in rows})
        self._run_chunks({r.slot: r for r in rows}, chunks, [])
        return sum(chunks.values())

    def _pad_rows(self, row_tokens: jax.Array, slot: int) -> jax.Array:
        """Broadcast a single request's tokens into a full-slot batch."""
        B = self.cfg.max_batch_slots
        S = row_tokens.shape[1]
        out = jnp.zeros((B, S), jnp.int32)
        return out.at[slot].set(row_tokens[0])

    # --------------------------------------------------------------- decode
    def _decode_once(self, rows: Optional[list[Request]] = None) -> int:
        """One-token decode for every DECODE row (or just ``rows``);
        returns the number of tokens generated."""
        active = (rows if rows is not None else
                  [r for r in self._slot_req
                   if r is not None and r.phase is Phase.DECODE])
        if not active:
            return 0
        t0 = self._now()
        B = self.cfg.max_batch_slots
        tokens = np.zeros((B, 1), np.int32)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
        ids = self._adapter_ids()
        logits, new_cache = self._decode_fn(
            self.params, self.adapters.slots, self.cache,
            jnp.asarray(tokens), ids,
        )
        self._merge_cache(new_cache, rows=[r.slot for r in active])
        # sampled tokens must reach Python for generation/finish
        # bookkeeping: ONE batched transfer per step is the right shape
        # libra: ignore[host-sync]
        toks = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        if self.tracer.enabled:
            t1 = self._now()
            for r in active:
                self.tracer.span(slot_track(r.slot), EV_DECODE_STEP,
                                 t0, t1, rid=r.request_id)
        for r in active:
            r.generated.append(int(toks[r.slot]))
            self._maybe_finish(r)
        return len(active)

    def _maybe_finish(self, req: Request) -> None:
        done = len(req.generated) >= req.max_new_tokens
        if self.cfg.eos_token >= 0 and req.generated[-1] == self.cfg.eos_token:
            done = True
        if not done:
            return
        now = self._now()
        req.finish_time = now
        req.phase = Phase.FINISHED
        self._commit(req, now)
        self._slot_req[req.slot] = None
        self._free_slots.append(req.slot)
        self.finished.append(req)
        if self.tracer.enabled:
            self.tracer.instant(slot_track(req.slot), EV_FINISH, now,
                                rid=req.request_id,
                                tokens=len(req.output_tokens))
            att = req.ttft_attribution()
            if att is not None:
                self.tracer.instant(
                    TRACK_QUEUE, EV_TTFT_ATTRIBUTION, now,
                    rid=req.request_id, ttft=req.ttft,
                    **{c: att.get(c, 0.0) for c in ATTRIB_CATEGORIES})
            if req.ttft_predicted is not None and req.ttft is not None:
                self.tracer.instant(
                    TRACK_QUEUE, EV_CALIBRATION, now, rid=req.request_id,
                    predicted=req.ttft_predicted, actual=req.ttft)

    def _commit(self, req: Request, now: float) -> None:
        """Scatter the request's new KV into its running blocks and fold them
        into the dependency tree."""
        if self._state_reusable:
            # recurrent state is not per-token pool KV: release the running
            # blocks and fold the staged boundary snapshot (if any) into the
            # unified pool as a STATE node instead
            self.manager.abort_running(req.request_id)
            if req.staged_state is not None:
                node = self.manager.commit_state(
                    req.adapter_id, req.prompt[: req.state_capture_at], now)
                # demand evictions that freed the snapshot's blocks must hit
                # the data plane BEFORE the store overwrites those rows
                self._execute_swaps(self.manager.drain_ops())
                if node is not None:
                    self.state_cache.store(node.hbm_blocks, req.staged_state)
                req.staged_state = None
            self.manager.unpin(req.pinned)
            return
        m = req.lookup.match
        prefix = m.matched_tokens
        full = req.full_tokens
        bs = self.cfg.block_size
        suffix_total = len(full) - prefix
        cache_tokens = (suffix_total // bs) * bs
        if cache_tokens > 0 and self.manager.config.reuse_history_kv:
            blocks = self.manager.running_blocks(req.request_id)
            keep = blocks[: cache_tokens // bs]
            k, v = self._read_dense(req.slot, prefix, prefix + cache_tokens)
            self.kv_pool.scatter(keep, k, v)
        self.manager.commit(req.request_id, req.lookup, full, now)
        self.manager.unpin(req.pinned)

    # ------------------------------------------------------------ swaps
    def _execute_swaps(self, ops, req: Optional[Request] = None) -> None:
        for op in ops:
            t0 = self._now()
            if op.node_kind is NodeKind.LORA:
                if op.kind is SwapKind.SWAP_IN:
                    self.adapters.load(op.lora_id)
                elif op.kind in (SwapKind.SWAP_OUT, SwapKind.DROP):
                    self.adapters.unload(op.lora_id)
            elif op.node_kind is NodeKind.STATE:
                # whole-snapshot moves through the two-tier StateCache
                if op.kind is SwapKind.SWAP_IN:
                    self.state_cache.swap_in(op.src_blocks, op.dst_blocks)
                elif op.kind is SwapKind.SWAP_OUT:
                    self.state_cache.swap_out(op.src_blocks, op.dst_blocks)
                # DROP: nothing physical to do
            else:
                if op.kind is SwapKind.SWAP_IN:
                    self.kv_pool.swap_in(op.src_blocks, op.dst_blocks)
                elif op.kind is SwapKind.SWAP_OUT:
                    self.kv_pool.swap_out(op.src_blocks, op.dst_blocks)
                # DROP: nothing physical to do
            t1 = self._now()
            if req is not None:
                # cold-start accounting (paper Fig. 12): swap-ins only
                if op.kind is SwapKind.SWAP_IN:
                    if op.node_kind is NodeKind.LORA:
                        req.lora_coldstart += t1 - t0
                    else:
                        req.kv_coldstart += t1 - t0
                # TTFT attribution: every op on an admission's critical
                # path is charged — demand evictions that freed this
                # request's blocks ride the swap_in bucket
                lora_in = (op.node_kind is NodeKind.LORA
                           and op.kind is SwapKind.SWAP_IN)
                req.charge("lora_load" if lora_in else "swap_in", t1)
            if self.tracer.enabled:
                self.tracer.span(
                    TRACK_SWAPPER, "swap." + op.kind.value, t0, t1,
                    kind=op.node_kind.name, lora=op.lora_id,
                    bytes=op.nbytes, node=op.node_id)

    # ------------------------------------------------------------- helpers
    def _adapter_ids(self, base_rows: tuple[int, ...] = ()) -> jax.Array:
        """Per-row adapter slots for the SGMV path.

        A request whose adapter was evicted mid-flight must NOT silently run
        through slot 0 (someone else's LoRA): reload it, charging the
        cold-start to the request. Raises if no slot can be freed.

        Rows still prefilling inside their declared shared span — and any
        slot in ``base_rows`` (the eager path's explicit per-span override) —
        get id -1: the LoRA delta is masked to zero (base-model row), so the
        span's KV is adapter-independent AND the dispatch needs no adapter
        slot at all (a prefill can start from a shared-prefix hit while its
        adapter is still cold; the reload is deferred to the first span past
        the boundary)."""
        ids = np.zeros((self.cfg.max_batch_slots,), np.int32)
        for r in self._slot_req:
            if r is not None:
                if r.slot in base_rows or (
                        r.phase is Phase.PREFILLING
                        and r.prefill_pos < self._shared_bound(r)):
                    ids[r.slot] = -1
                    continue
                s = self.adapters.slot_of(r.adapter_id)
                if s is None:
                    s = self._reload_adapter(r)
                ids[r.slot] = s
        return jnp.asarray(ids)

    def _reload_adapter(self, req: Request) -> int:
        """Reload ``req``'s evicted adapter, evicting an idle resident one
        (not referenced by any active request) if all slots are taken."""
        t0 = self._now()
        try:
            s = self.adapters.load(req.adapter_id)
        except RuntimeError:
            active = {r.adapter_id for r in self._slot_req if r is not None}
            victim = next(
                (a for a in self.adapters.resident if a not in active), None)
            if victim is None:
                raise  # every slot pinned by an in-flight request
            self.adapters.unload(victim)
            s = self.adapters.load(req.adapter_id)
        req.lora_coldstart += self._now() - t0
        req.charge("lora_load", self._now())
        return s

    def _set_len(self, slot: int, value: int) -> None:
        self.cache["len"] = self.cache["len"].at[slot].set(value)

    def _merge_cache(self, new_cache: dict, rows: list[int]) -> None:
        """Adopt updated rows from ``new_cache``; keep other rows unchanged.

        Keyed on the cache layout ('len' is (B,), all other leaves are
        layer-stacked (L, B, ...)) rather than guessing the batch axis from
        shapes, which breaks when num_layers == max_batch_slots."""
        B = self.cfg.max_batch_slots
        mask = np.zeros((B,), bool)
        for r in rows:
            mask[r] = True
        sel = jnp.asarray(mask)
        merged = {}
        for key, new in new_cache.items():
            if key == "len":
                m = sel
            else:
                m = sel.reshape((1, B) + (1,) * (new.ndim - 2))
            merged[key] = jnp.where(m, new, self.cache[key])
        self.cache = merged

    def _write_dense(self, slot: int, start: int, k, v) -> None:
        """Place gathered prefix KV (L, T, H, D) into the dense cache rows."""
        T = k.shape[1]
        if self.model_cfg.mla is not None:
            m = self.model_cfg.mla
            latent = k[..., 0, : m.kv_lora_rank]
            krope = k[..., 0, m.kv_lora_rank : m.kv_lora_rank + m.qk_rope_head_dim]
            self.cache["latent"] = jax.lax.dynamic_update_slice(
                self.cache["latent"], latent[:, None].astype(self.cache["latent"].dtype),
                (0, slot, start, 0))
            self.cache["krope"] = jax.lax.dynamic_update_slice(
                self.cache["krope"], krope[:, None].astype(self.cache["krope"].dtype),
                (0, slot, start, 0))
            return
        self.cache["k"] = jax.lax.dynamic_update_slice(
            self.cache["k"], k[:, None].astype(self.cache["k"].dtype),
            (0, slot, start, 0, 0))
        self.cache["v"] = jax.lax.dynamic_update_slice(
            self.cache["v"], v[:, None].astype(self.cache["v"].dtype),
            (0, slot, start, 0, 0))

    def _read_dense(self, slot: int, start: int, end: int):
        """Read dense cache rows back as (L, T, H, D) for pool scatter."""
        if self.model_cfg.mla is not None:
            # pool row == concat(latent, krope): kv_spec.head_dim is
            # constructed as kv_lora_rank + qk_rope_head_dim
            latent = self.cache["latent"][:, slot, start:end]
            krope = self.cache["krope"][:, slot, start:end]
            k = jnp.concatenate([latent, krope], axis=-1)
            return k[:, :, None, :], None
        k = self.cache["k"][:, slot, start:end]
        v = self.cache["v"][:, slot, start:end]
        return k, v

    def _observe_batch_size(self, now: float) -> None:
        """Report the unified mixed-batch token load to the swapper.

        The signal is the per-step REAL token count of the (mixed or
        alternate) batch — decode rows contribute 1 token, prefill rows
        their chunk slice — averaged over the last 5 s. Before the mixed
        scheduler the swapper saw decode-slot occupancy only, blind to the
        prefill share of the batch (Eq. 3's BS under-counted under load)."""
        while self._batch_tokens and self._batch_tokens[0][0] < now - 5.0:
            self._batch_tokens.popleft()
        # an empty window means the engine has been idle for 5 s: observe 0
        # so the demand signal decays instead of freezing at the last busy
        # value (idle steps append nothing to the deque)
        avg = (sum(b for _, b in self._batch_tokens) / len(self._batch_tokens)
               if self._batch_tokens else 0.0)
        self.swapper.observe_batch_size(avg)
