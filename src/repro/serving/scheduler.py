"""Mixed prefill+decode step scheduling (Sarathi-style) under a token budget.

The engine's alternate mode runs one bucketed-prefill call and one decode
call per step: decode tokens wait for the prefill dispatch and vice versa,
and the prefill chunk size is a static knob (``EngineConfig.prefill_chunk``).
This module provides the two pieces that collapse a step into ONE mixed
batch:

* :func:`plan_step` — packs every active decode slot (1 token each) plus
  prefill chunk slices into a single per-step token budget, FCFS over the
  prefill rows with ``prefill_chunk`` surviving as the per-row ceiling;
* :class:`TokenBudgetController` — makes the budget *dynamic*: an EMA of
  measured step latency is servo'd against ``target_step_ms``, shrinking the
  prefill share when steps run long (decode TPOT stays bounded under load)
  and growing it back when there is headroom (prefill throughput / TTFT).

The planner is pure and jit-free; the resulting batch still pads to the
existing power-of-two buckets (serving/prefill.py) so the jit cache stays
bounded no matter what budgets the controller picks.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass
class StepPlan:
    """One engine step's mixed batch: who contributes which tokens."""

    decode_slots: tuple[int, ...]  # slots generating 1 token each
    prefill_chunks: dict[int, int]  # slot -> suffix tokens fed this step
    budget: int  # token budget the plan was packed against

    @property
    def tokens(self) -> int:
        """Real tokens in the mixed batch (the unified batch-size signal)."""
        return len(self.decode_slots) + sum(self.prefill_chunks.values())

    @property
    def max_chunk(self) -> int:
        return max(self.prefill_chunks.values(), default=0)


def plan_step(
    decode_slots: Sequence[int],
    prefill_rows: Sequence[tuple[int, int]],  # (slot, suffix tokens left)
    *,
    budget: int,
    chunk_ceiling: int,
    fast_slots: frozenset[int] = frozenset(),
) -> StepPlan:
    """Pack one mixed step: decode slots first (1 token each, never dropped),
    then prefill rows — taken in the caller's order; the engine passes them
    oldest-admission-first so leftover budget favors the longest-waiting
    request — split the remaining budget EVENLY, waterfilling any leftover
    up to the per-row ``chunk_ceiling``.

    Even split (not Sarathi's pure FCFS fill) because the batched call's
    cost is shape-driven — (B, bucket) with bucket padding — so starving
    trailing rows saves nothing on the call while serializing their TTFT;
    coalescing every row into the same call is the whole point of the
    bucketed subsystem. The budget still bounds step latency: it caps the
    total real tokens and thereby the bucket the batch pads to.

    Interactive fast lane: rows whose slot is in ``fast_slots`` (the engine
    passes its interactive-tier requests) are served FIRST and greedily — up
    to ``chunk_ceiling`` each, in row order — before the remaining budget is
    split evenly over the slow rows. Their TTFT then scales with their own
    prompt length, not with however many batch-tier prefills happen to be in
    flight. With ``fast_slots`` empty the plan is exactly the legacy one.

    Progress guarantee: if any prefill row is pending, the first one receives
    at least 1 token even when decode alone exhausts the budget — a saturated
    decode batch must not livelock admission (TTFT would diverge).
    """
    if chunk_ceiling < 1:
        raise ValueError("chunk_ceiling must be >= 1")
    decode_slots = tuple(decode_slots)
    rows = [(slot, left) for slot, left in prefill_rows if left > 0]
    chunks: dict[int, int] = {}
    if rows:
        remaining = max(budget - len(decode_slots), 0)
        fast = [(s, l) for s, l in rows if s in fast_slots]
        slow = [(s, l) for s, l in rows if s not in fast_slots]
        for slot, left in fast:  # fast lane: greedy fill, row order
            take = min(left, chunk_ceiling, remaining)
            if take > 0:
                chunks[slot] = take
                remaining -= take
        if slow:
            share = min(chunk_ceiling, remaining // len(slow))
            if share == 0:
                # fewer budget tokens than rows: 1 token each while they last
                for slot, _ in slow[:remaining]:
                    chunks[slot] = 1
            else:
                for slot, left in slow:
                    take = min(left, share)
                    chunks[slot] = take
                    remaining -= take
                for slot, left in slow:  # waterfill leftover in row order
                    if remaining <= 0:
                        break
                    extra = min(left, chunk_ceiling) - chunks[slot]
                    if extra > 0:
                        extra = min(extra, remaining)
                        chunks[slot] += extra
                        remaining -= extra
        if not chunks:
            # never zero rows — the progress guarantee
            chunks[rows[0][0]] = 1
    return StepPlan(decode_slots=decode_slots, prefill_chunks=chunks,
                    budget=budget)


@dataclasses.dataclass
class TokenBudgetController:
    """Latency-servo for the per-step token budget (multiplicative AIMD).

    ``observe(step_ms)`` feeds the measured wall time of each engine step
    into an EMA; when ``target_step_ms > 0`` the budget shrinks by
    ``shrink`` whenever the EMA overshoots the target and grows by ``grow``
    when it sits below ``headroom * target`` (the dead band between the two
    prevents ping-pong). ``target_step_ms <= 0`` disables adaptation and the
    budget pins to ``max_budget`` — the static-budget ablation.
    """

    max_budget: int
    target_step_ms: float = 0.0
    min_budget: int = 1
    ema_alpha: float = 0.25
    grow: float = 1.25
    shrink: float = 0.7
    headroom: float = 0.8

    ema_ms: float = dataclasses.field(default=0.0, init=False)
    steps: int = dataclasses.field(default=0, init=False)
    _budget: float = dataclasses.field(default=0.0, init=False)

    def __post_init__(self) -> None:
        if self.max_budget < 1:
            raise ValueError("max_budget must be >= 1")
        self.min_budget = max(1, min(self.min_budget, self.max_budget))
        self._budget = float(self.max_budget)

    @property
    def budget(self) -> int:
        return int(round(self._budget))

    def reset(self) -> None:
        """Forget the latency EMA and re-pin the budget to ``max_budget`` —
        benchmark warm-up boundaries call this via ``engine.reset_metrics``
        so steady-state measurements start from the controller's init
        state."""
        self.ema_ms = 0.0
        self.steps = 0
        self._budget = float(self.max_budget)

    def observe(self, step_ms: float) -> None:
        self.steps += 1
        if self.steps == 1:
            self.ema_ms = step_ms
        else:
            a = self.ema_alpha
            self.ema_ms = a * step_ms + (1.0 - a) * self.ema_ms
        if self.target_step_ms <= 0:
            return
        if self.ema_ms > self.target_step_ms:
            self._budget = max(float(self.min_budget),
                               self._budget * self.shrink)
        elif self.ema_ms < self.headroom * self.target_step_ms:
            self._budget = min(float(self.max_budget),
                               max(self._budget * self.grow,
                                   self._budget + 1.0))
