"""Bucketed, jit-cached, multi-request batch prefill.

The TTFT hot path. The eager seed path ran ``model.extend`` once per
admitted request with the request's *exact* suffix length — every distinct
prompt length was a fresh XLA compile, and each call dispatched unfused ops
over all batch slots to advance one row. This module replaces it with:

* **length buckets** — each suffix chunk is padded to a small set of
  power-of-two lengths, so XLA compiles at most ``len(buckets)`` distinct
  shapes no matter how many prompt lengths the trace contains;
* **request coalescing** — every request admitted in the same engine step
  rides in ONE batched ``extend`` call, with per-row ``adapter_ids``
  batching heterogeneous LoRAs through the SGMV path;
* **chunking** — suffixes longer than ``chunk`` are fed ``chunk`` tokens per
  engine step, interleaved with decode, so a 2k-token prompt cannot hold the
  decode loop hostage (chunked prefill a la Sarathi/InfiniLoRA's
  prefill–decode disaggregation, on a single engine).

Correctness relies on the models' row-masked extend (``true_lens``): pad
positions neither write KV/recurrent state nor advance ``len``, and each
row's next-token logits are gathered at its own last *real* position.

:func:`assemble_batch` additionally accepts decode rows (``true_lens == 1``)
so the Sarathi-style mixed scheduler (serving/scheduler.py) can pack prefill
chunks and decode tokens into ONE batched ``extend`` per engine step.

Adapter-id contract: ``adapter_ids`` is per ROW per dispatch. A NEGATIVE id
marks a base-model row — the SGMV delta is masked to zero, which is how the
engine computes a request's declared adapter-independent shared prefix
(cross-adapter KV sharing). A chunk therefore may never straddle the shared
boundary; the engine clamps chunks to land on it (``_clamp_shared_chunks``).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_buckets(min_bucket: int, chunk: int) -> tuple[int, ...]:
    """Power-of-two bucket lengths from ``min_bucket`` up to ``chunk``
    (``chunk`` itself is always the last bucket)."""
    if min_bucket < 1 or chunk < 1:
        raise ValueError("min_bucket and chunk must be >= 1")
    if min_bucket > chunk:
        min_bucket = chunk
    buckets = []
    b = max(1, min_bucket)
    while b < chunk:
        buckets.append(b)
        b *= 2
    buckets.append(chunk)
    return tuple(sorted(set(buckets)))


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= n (n <= 0 maps to the smallest bucket)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"suffix chunk of {n} tokens exceeds the largest "
                     f"bucket {buckets[-1]} — chunk before bucketing")


def assemble_batch(
    n_slots: int,
    bucket: int,
    prefill_chunks: Mapping[int, Sequence[int]],
    decode_tokens: Mapping[int, int] | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Assemble one (possibly mixed) row-masked batch.

    ``prefill_chunks`` maps slot -> that row's suffix token slice for this
    step; ``decode_tokens`` maps slot -> the row's last generated token —
    decode rows ride in the same batch as 1-token rows (``true_lens == 1``),
    which is what makes Sarathi-style mixed scheduling a pure batch-assembly
    concern: the row-masked ``extend`` already handles heterogeneous per-row
    lengths. Returns (tokens (B, bucket) int32, true_lens (B,), row_mask (B,)).
    """
    tokens = np.zeros((n_slots, bucket), np.int32)
    true_lens = np.zeros((n_slots,), np.int32)
    row_mask = np.zeros((n_slots,), bool)
    for slot, toks in prefill_chunks.items():
        c = len(toks)
        if c > bucket:
            raise ValueError(f"chunk of {c} tokens exceeds bucket {bucket}")
        tokens[slot, :c] = toks
        true_lens[slot] = c
        row_mask[slot] = True
    for slot, tok in (decode_tokens or {}).items():
        if row_mask[slot]:
            raise ValueError(f"slot {slot} is both prefilling and decoding")
        tokens[slot, 0] = tok
        true_lens[slot] = 1
        row_mask[slot] = True
    return tokens, true_lens, row_mask


@dataclasses.dataclass
class PrefillStats:
    calls: int = 0          # batched prefill dispatches
    rows: int = 0           # request-chunks processed across all calls
    tokens: int = 0         # real (unpadded) suffix tokens processed
    pad_tokens: int = 0     # bucket-padding overhead tokens

    @property
    def mean_batch(self) -> float:
        return self.rows / self.calls if self.calls else 0.0


class BatchPrefill:
    """Per-bucket jit cache around row-masked ``model.extend``.

    One compiled executable per bucket length serves every (suffix-length,
    active-row-set) combination: tokens are padded to the bucket, rows not
    prefilling this step are masked out, and the merged cache keeps their
    contents bit-identical.
    """

    def __init__(self, model, buckets: Sequence[int]):
        self.model = model
        self.buckets = tuple(sorted(buckets))
        self._fns: dict[int, object] = {}
        self.stats = PrefillStats()

    # ------------------------------------------------------------- bucketing
    def bucket_for(self, n: int) -> int:
        return bucket_for(n, self.buckets)

    # --------------------------------------------------------- compile probe
    @property
    def compile_count(self) -> int:
        """Number of distinct lowered shapes across all bucket functions
        (jit tracing-cache probe) — the test invariant is
        ``compile_count <= len(buckets)``."""
        total = 0
        for fn in self._fns.values():
            size = getattr(fn, "_cache_size", None)
            total += size() if callable(size) else 1
        return total

    # ------------------------------------------------------------- execution
    def _build(self, bucket: int):
        model = self.model

        def step(params, lora, cache, tokens, start, true_lens, row_mask,
                 adapter_ids):
            logits, new_cache = model.extend(
                params, cache, tokens, start, lora=lora,
                adapter_ids=adapter_ids, all_logits=True, true_lens=true_lens,
            )
            B = tokens.shape[0]
            # keyed row-merge: 'len' is (B,), every other cache leaf is
            # layer-stacked (L, B, …) — never guess the batch axis by shape
            # (L == B would silently merge along layers)
            merged = {}
            for key, new in new_cache.items():
                if key == "len":
                    m = row_mask
                else:
                    m = row_mask.reshape((1, B) + (1,) * (new.ndim - 2))
                merged[key] = jnp.where(m, new, cache[key])
            # each row's next-token logits live at its own last real position
            idx = jnp.maximum(true_lens - 1, 0)
            last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0, :]
            return last, merged

        return jax.jit(step)

    def __call__(self, params, lora, cache, tokens, start, true_lens,
                 row_mask, adapter_ids, stat_mask=None):
        """Run one coalesced (possibly mixed) chunk.

        tokens: (B, bucket) int32 — pad with any token id beyond true_lens
        start: (B,) current cache lengths; true_lens: (B,) real chunk tokens
        (0 for rows riding along); row_mask: (B,) bool participating rows.
        ``stat_mask`` (B,) bool restricts PrefillStats accounting to the
        actual prefill chunk rows — mixed batches carry decode rider rows
        (true_lens == 1) that must not inflate avg_prefill_batch or count
        their bucket padding as prefill overhead. Defaults to ``row_mask``.
        Returns (per-row last-real-token logits (B, V), merged cache).
        """
        bucket = int(tokens.shape[1])
        if bucket not in self._fns:
            if bucket not in self.buckets:
                raise ValueError(f"tokens padded to {bucket}, not a "
                                 f"configured bucket {self.buckets}")
            self._fns[bucket] = self._build(bucket)
        sm = row_mask if stat_mask is None else stat_mask
        sm = np.asarray(sm)
        real = int(np.asarray(true_lens)[sm].sum())
        nrows = int(sm.sum())
        self.stats.calls += 1
        self.stats.rows += nrows
        self.stats.tokens += real
        self.stats.pad_tokens += nrows * bucket - real
        return self._fns[bucket](params, lora, cache, tokens, start,
                                 true_lens, row_mask, adapter_ids)
