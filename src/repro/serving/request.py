"""Request model + per-request latency accounting."""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"  # admitted, prefix gathered, suffix not yet started
    PREFILLING = "prefilling"  # chunked batch prefill in flight
    DECODE = "decode"
    FINISHED = "finished"
    ABORTED = "aborted"


@dataclasses.dataclass
class Request:
    request_id: str
    adapter_id: str
    prompt: tuple[int, ...]
    max_new_tokens: int
    submit_time: float = 0.0
    # filled during serving
    phase: Phase = Phase.WAITING
    generated: list[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # cold-start breakdown (paper Fig. 12)
    lora_coldstart: float = 0.0
    kv_coldstart: float = 0.0
    matched_tokens: int = 0
    hbm_hit_tokens: int = 0
    # cross-adapter prefix sharing: leading prompt tokens that are
    # adapter-independent (e.g. a product system prompt). The engine computes
    # them with the adapter INACTIVE (base-model rows) so their KV is
    # bit-identical across adapters and cacheable on the shared trunk. 0 =
    # whole prompt is adapter-specific (legacy behavior).
    shared_prefix_len: int = 0
    # engine bookkeeping
    slot: int = -1
    lookup: object = None
    pinned: list = dataclasses.field(default_factory=list)
    # chunked-prefill bookkeeping: absolute position of the next suffix
    # token to prefill, and how many batched chunks this request rode in
    prefill_pos: int = 0
    prefill_chunks: int = 0
    # recurrent-state snapshot bookkeeping: the prefix boundary to capture a
    # snapshot at (len(prompt)-1 so an identical repeat can resume and still
    # recompute its last token for logits; -1 = no capture), and the
    # captured flat state staged until commit folds it into the pool
    state_capture_at: int = -1
    staged_state: object = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = max(1, len(self.generated) - 1)
        return (self.finish_time - self.first_token_time) / n

    @property
    def queue_time(self) -> Optional[float]:
        if self.admit_time is None:
            return None
        return self.admit_time - self.submit_time

    @property
    def full_tokens(self) -> tuple[int, ...]:
        return self.prompt + tuple(self.generated)
