"""Request model + per-request latency accounting.

Arrival-time semantics: ``submit_time`` is ``None`` until the request is
handed to the engine, at which point :meth:`ServingEngine.submit` stamps the
engine clock — UNLESS the caller pre-set it (trace replay with backdated
arrivals). All queue/TTFT metrics and the deadline-aware admission order are
measured against this value, so a replayed trace carries its true arrival
pattern instead of the wall time the replay loop happened to call submit().

SLO tiers: ``priority`` orders admission strictly (higher first — e.g.
interactive=1 vs batch=0); ``deadline`` is an absolute engine-clock time the
first token should land by. Within a priority tier the engine admits by
least deadline slack (deadline − now − estimated TTFT from the cost model),
then FCFS. A higher-priority request that cannot be admitted may PREEMPT a
strictly-lower-priority running victim: the victim's computed KV (or
recurrent-state snapshot) is folded into the two-tier cache pool — demoted
to host by the swapper under pressure, not discarded — and the victim
requeues. On resume it matches its own swapped prefix and continues
token-identically; generated tokens survive in ``carried`` and
``output_tokens`` presents the full carried+generated stream.

Abort semantics: :meth:`ServingEngine.abort` (and the ``run()`` drain on
step exhaustion) moves a request to ``Phase.ABORTED`` after releasing every
resource it held — pins, running blocks, slot, staged state. An aborted
request keeps whatever tokens it produced but is never counted as finished.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"  # admitted, prefix gathered, suffix not yet started
    PREFILLING = "prefilling"  # chunked batch prefill in flight
    DECODE = "decode"
    FINISHED = "finished"
    ABORTED = "aborted"  # released by the engine's abort/drain path


# SLO tier conventions (any int works; higher = more latency-sensitive)
PRIORITY_BATCH = 0
PRIORITY_INTERACTIVE = 1


@dataclasses.dataclass
class Request:
    request_id: str
    adapter_id: str
    prompt: tuple[int, ...]
    max_new_tokens: int
    # None until submit(); pre-set by trace replay to carry true arrivals
    # (submit() honors a caller-provided value instead of clobbering it)
    submit_time: Optional[float] = None
    # SLO tier: admission is ordered by (priority desc, deadline slack asc,
    # submit_time); preemption only ever evicts a STRICTLY lower priority
    priority: int = PRIORITY_BATCH
    # absolute engine-clock first-token deadline (None = no deadline)
    deadline: Optional[float] = None
    # filled during serving
    phase: Phase = Phase.WAITING
    generated: list[int] = dataclasses.field(default_factory=list)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # cold-start breakdown (paper Fig. 12)
    lora_coldstart: float = 0.0
    kv_coldstart: float = 0.0
    matched_tokens: int = 0
    hbm_hit_tokens: int = 0
    # cross-adapter prefix sharing: leading prompt tokens that are
    # adapter-independent (e.g. a product system prompt). The engine computes
    # them with the adapter INACTIVE (base-model rows) so their KV is
    # bit-identical across adapters and cacheable on the shared trunk. 0 =
    # whole prompt is adapter-specific (legacy behavior).
    shared_prefix_len: int = 0
    # engine bookkeeping
    slot: int = -1
    lookup: object = None
    pinned: list = dataclasses.field(default_factory=list)
    # chunked-prefill bookkeeping: absolute position of the next suffix
    # token to prefill, and how many batched chunks this request rode in
    prefill_pos: int = 0
    prefill_chunks: int = 0
    # recurrent-state snapshot bookkeeping: the prefix boundary to capture a
    # snapshot at (len(prompt)-1 so an identical repeat can resume and still
    # recompute its last token for logits; -1 = no capture), and the
    # captured flat state staged until commit folds it into the pool
    state_capture_at: int = -1
    staged_state: object = None
    # preemption bookkeeping: tokens generated before a preemption are folded
    # into the (growing) prompt so the resume lookup matches the victim's own
    # committed KV/state — they live on in ``carried`` and the resume decode
    # continues token-identically from where preemption cut it off
    carried: list[int] = dataclasses.field(default_factory=list)
    preempt_count: int = 0
    # highest absolute prompt position this request had already computed
    # before a preemption folded it back to WAITING: prefill work below
    # this boundary is classified as ``recompute`` in the TTFT attribution
    # (0 for a never-preempted request — all prefill is fresh compute)
    recompute_boundary: int = 0
    # TTFT attribution (repro.obs): an EXACT additive partition of
    # [submit_time, first_token_time] into the categories of
    # ``obs.ATTRIB_CATEGORIES``. The engine advances ``attrib_cursor`` at
    # every charge point (queue end, per swap op, per prefill dispatch, ...)
    # so sum(attribution.values()) == ttft by construction. Accounting is
    # host-float cheap and always on (like lora/kv_coldstart); span/event
    # emission is what the tracer gates.
    attribution: dict[str, float] = dataclasses.field(default_factory=dict)
    attrib_cursor: Optional[float] = None
    # estimate_ttft sampled at first admission when tracing is armed, for
    # the predicted-vs-actual calibration series
    ttft_predicted: Optional[float] = None

    # -- TTFT attribution charging (called by the engine) -------------------

    def charge(self, category: str, t: float) -> None:
        """Attribute [attrib_cursor, t) to ``category`` and advance the
        cursor. No-op before submit or after the first token closed the
        window (a resumed decode-phase victim charges nothing)."""
        if self.attrib_cursor is None or self.first_token_time is not None:
            return
        dt = t - self.attrib_cursor
        if dt > 0:
            self.attribution[category] = self.attribution.get(category, 0.0) + dt
            self.attrib_cursor = t

    def charge_prefill(self, t: float, tokens: int, hist_tokens: int) -> None:
        """Split [attrib_cursor, t) between ``recompute`` (the share of this
        dispatch's tokens that rebuild already-seen history, i.e. positions
        below len(prompt)-1 lost to eviction/preemption) and ``compute``."""
        if self.attrib_cursor is None or self.first_token_time is not None:
            return
        dt = t - self.attrib_cursor
        if dt > 0:
            frac = min(max(hist_tokens, 0), tokens) / tokens if tokens > 0 else 0.0
            rec = dt * frac
            if rec > 0:
                self.attribution["recompute"] = self.attribution.get("recompute", 0.0) + rec
            self.attribution["compute"] = self.attribution.get("compute", 0.0) + (dt - rec)
            self.attrib_cursor = t

    def ttft_attribution(self) -> Optional[dict[str, float]]:
        """The additive breakdown, or None before the first token."""
        if self.ttft is None:
            return None
        return dict(self.attribution)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None or self.submit_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = max(1, len(self.carried) + len(self.generated) - 1)
        return (self.finish_time - self.first_token_time) / n

    @property
    def queue_time(self) -> Optional[float]:
        if self.admit_time is None or self.submit_time is None:
            return None
        return self.admit_time - self.submit_time

    @property
    def full_tokens(self) -> tuple[int, ...]:
        return self.prompt + tuple(self.generated)

    @property
    def output_tokens(self) -> tuple[int, ...]:
        """The complete generated stream: tokens produced before any
        preemption (folded into the prompt, kept in ``carried``) plus the
        current ``generated`` tail. Equals ``tuple(generated)`` for a request
        that was never preempted."""
        return tuple(self.carried) + tuple(self.generated)
