"""Serving metrics aggregation (TTFT / TPOT / throughput / breakdowns)."""

from __future__ import annotations

import dataclasses
import statistics
from typing import Iterable

from .request import Request


@dataclasses.dataclass
class ServingReport:
    n_finished: int
    avg_ttft: float
    p99_ttft: float
    avg_tpot: float
    avg_queue: float
    avg_lora_coldstart: float
    avg_kv_coldstart: float
    throughput_qps: float
    kv_hit_rate: float
    lora_hit_rate: float
    invalid_kv_fraction: float
    hbm_utilization: float
    # prefill subsystem (serving/prefill.py)
    p99_queue: float = 0.0
    avg_prefill_batch: float = 0.0  # requests coalesced per batched prefill
    prefill_compiles: int = 0  # distinct lowered prefill shapes (≤ #buckets)
    # step scheduler (serving/scheduler.py)
    p99_tpot: float = 0.0  # decode-latency tail the mixed budget bounds
    avg_step_ms: float = 0.0  # mean measured engine-step wall time
    ema_step_ms: float = 0.0  # TokenBudgetController's latency EMA
    budget_utilization: float = 0.0  # mixed-batch tokens / step budget
    # recurrent-state prefix cache (kvcache/state_cache.py): token-weighted
    # snapshot hit rate, symmetric with kv_hit_rate for KV layouts
    state_hit_rate: float = 0.0
    # request-lifecycle accounting: a run() that exhausts max_steps drains
    # its leftovers through the abort path and reports them here instead of
    # silently pretending the trace completed
    n_aborted: int = 0  # aborted (drained or explicit abort()) requests
    n_unfinished: int = 0  # still WAITING/in-flight when the report was cut
    n_preempted: int = 0  # preemption events (a victim can count twice)
    # libra-trace TTFT attribution (repro.obs): mean per-request seconds the
    # first token spent recomputing evicted/preempted prefix work, and mean
    # dispatch stall — both additive slices of TTFT (always measured; the
    # tracer only gates event emission)
    avg_recompute: float = 0.0
    avg_stall: float = 0.0
    # estimate_ttft calibration over requests that had a prediction sampled
    # at admission (tracing armed): mean |predicted − actual| and signed bias
    ttft_pred_mae: float = 0.0
    ttft_pred_bias: float = 0.0

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _p(vals, q):
    if not vals:
        return 0.0
    vals = sorted(vals)
    idx = min(len(vals) - 1, int(q * len(vals)))
    return vals[idx]


def summarize(
    finished: Iterable[Request],
    wall_time: float,
    *,
    kv_hit_rate: float = 0.0,
    lora_hit_rate: float = 0.0,
    invalid_kv_fraction: float = 0.0,
    hbm_utilization: float = 0.0,
    avg_prefill_batch: float = 0.0,
    prefill_compiles: int = 0,
    avg_step_ms: float = 0.0,
    ema_step_ms: float = 0.0,
    budget_utilization: float = 0.0,
    state_hit_rate: float = 0.0,
    n_aborted: int = 0,
    n_unfinished: int = 0,
    n_preempted: int = 0,
) -> ServingReport:
    reqs = [r for r in finished if r.ttft is not None]
    ttfts = [r.ttft for r in reqs]
    tpots = [r.tpot for r in reqs if r.tpot is not None]
    queues = [r.queue_time for r in reqs if r.queue_time is not None]
    pred_errs = [
        r.ttft_predicted - r.ttft
        for r in reqs
        if getattr(r, "ttft_predicted", None) is not None
    ]
    return ServingReport(
        n_finished=len(reqs),
        avg_ttft=statistics.fmean(ttfts) if ttfts else 0.0,
        p99_ttft=_p(ttfts, 0.99),
        avg_tpot=statistics.fmean(tpots) if tpots else 0.0,
        avg_queue=statistics.fmean(queues) if queues else 0.0,
        avg_lora_coldstart=statistics.fmean([r.lora_coldstart for r in reqs]) if reqs else 0.0,
        avg_kv_coldstart=statistics.fmean([r.kv_coldstart for r in reqs]) if reqs else 0.0,
        throughput_qps=len(reqs) / wall_time if wall_time > 0 else 0.0,
        kv_hit_rate=kv_hit_rate,
        lora_hit_rate=lora_hit_rate,
        invalid_kv_fraction=invalid_kv_fraction,
        hbm_utilization=hbm_utilization,
        p99_queue=_p(queues, 0.99),
        avg_prefill_batch=avg_prefill_batch,
        prefill_compiles=prefill_compiles,
        p99_tpot=_p(tpots, 0.99),
        avg_step_ms=avg_step_ms,
        ema_step_ms=ema_step_ms,
        budget_utilization=budget_utilization,
        state_hit_rate=state_hit_rate,
        n_aborted=n_aborted,
        n_unfinished=n_unfinished,
        n_preempted=n_preempted,
        avg_recompute=statistics.fmean(
            [getattr(r, "attribution", {}).get("recompute", 0.0)
             for r in reqs]) if reqs else 0.0,
        avg_stall=statistics.fmean(
            [getattr(r, "attribution", {}).get("stall", 0.0)
             for r in reqs]) if reqs else 0.0,
        ttft_pred_mae=statistics.fmean(
            [abs(e) for e in pred_errs]) if pred_errs else 0.0,
        ttft_pred_bias=statistics.fmean(pred_errs) if pred_errs else 0.0,
    )
