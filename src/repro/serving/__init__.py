"""Serving layer: continuous-batching engine with FASTLIBRA cache management."""

from .engine import EngineConfig, ServingEngine
from .metrics import ServingReport, summarize
from .prefill import (
    BatchPrefill,
    PrefillStats,
    assemble_batch,
    bucket_for,
    make_buckets,
)
from .request import Phase, Request
from .scheduler import StepPlan, TokenBudgetController, plan_step

__all__ = [
    "BatchPrefill",
    "EngineConfig",
    "Phase",
    "PrefillStats",
    "Request",
    "ServingEngine",
    "ServingReport",
    "StepPlan",
    "TokenBudgetController",
    "assemble_batch",
    "bucket_for",
    "make_buckets",
    "plan_step",
    "summarize",
]
