"""Serving layer: continuous-batching engine with FASTLIBRA cache management."""

from .engine import EngineConfig, ServingEngine
from .metrics import ServingReport, summarize
from .prefill import BatchPrefill, PrefillStats, bucket_for, make_buckets
from .request import Phase, Request

__all__ = [
    "BatchPrefill",
    "EngineConfig",
    "Phase",
    "PrefillStats",
    "Request",
    "ServingEngine",
    "ServingReport",
    "bucket_for",
    "make_buckets",
    "summarize",
]
