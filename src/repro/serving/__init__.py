"""Serving layer: continuous-batching engine with FASTLIBRA cache management."""

from .engine import EngineConfig, ServingEngine
from .metrics import ServingReport, summarize
from .request import Phase, Request

__all__ = [
    "EngineConfig",
    "Phase",
    "Request",
    "ServingEngine",
    "ServingReport",
    "summarize",
]
