"""Fault-tolerant checkpointing (no external deps).

Design (orbax-like, minimal):
* a checkpoint = one directory ``step_<N>/`` containing per-leaf ``.npy``
  shards plus a JSON manifest (pytree structure, dtypes, shapes, step);
* writes go to ``step_<N>.tmp/`` and are atomically renamed — a crash
  mid-save never corrupts the latest checkpoint;
* ``save_async`` snapshots device arrays to host (blocking only for the
  device→host copy) and writes files on a background thread — training
  continues during serialization;
* restore reads into *whatever sharding the caller asks for* (the mesh may
  have changed — elastic restarts re-shard on load);
* ``keep`` old checkpoints are garbage-collected oldest-first.

Serving-side fault tolerance: :class:`RequestJournal` persists in-flight
request metadata so a restarted engine can re-enqueue them (§DESIGN 5).
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> pathlib.Path:
        self.wait()  # one async save in flight at a time
        host = [(n, np.asarray(l)) for n, l in _flatten_with_names(tree)]
        return self._write(step, tree, host)

    def save_async(self, step: int, tree) -> None:
        """Device→host copy now; file IO on a background thread."""
        self.wait()
        host = [(n, np.asarray(l)) for n, l in _flatten_with_names(tree)]

        def work():
            self._write(step, tree, host)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, tree, host) -> pathlib.Path:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(host):
            fn = f"leaf_{i:05d}.npy"
            np.save(tmp / fn, arr)
            manifest["leaves"].append(
                {"name": name, "file": fn, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)}
            )
        treedef = jax.tree_util.tree_structure(tree)
        manifest["treedef"] = str(treedef)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if not c.name.endswith(".tmp")]
        for old in ckpts[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(old)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
        ]
        return max(steps) if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings`` (same structure) re-shards onto the
        current mesh — elastic restart path."""
        d = self.dir / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves = [np.load(d / rec["file"]) for rec in manifest["leaves"]]
        treedef = jax.tree_util.tree_structure(like)
        if treedef.num_leaves != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, target needs "
                f"{treedef.num_leaves}"
            )
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        else:
            like_leaves = jax.tree.leaves(like)
            tree = jax.tree_util.tree_unflatten(
                treedef,
                [
                    jax.numpy.asarray(a, dtype=l.dtype)
                    for a, l in zip(leaves, like_leaves)
                ],
            )
        return tree


class RequestJournal:
    """Append-only journal of in-flight serving requests (crash recovery)."""

    def __init__(self, path: str | pathlib.Path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)

    def record_submit(self, request_id: str, adapter_id: str,
                      prompt: tuple, max_new_tokens: int) -> None:
        with self.path.open("a") as f:
            f.write(json.dumps({
                "event": "submit", "rid": request_id, "adapter": adapter_id,
                "prompt": list(prompt), "max_new": max_new_tokens,
            }) + "\n")

    def record_finish(self, request_id: str) -> None:
        with self.path.open("a") as f:
            f.write(json.dumps({"event": "finish", "rid": request_id}) + "\n")

    def replay(self) -> list[dict]:
        """Requests submitted but not finished (to re-enqueue on restart)."""
        if not self.path.exists():
            return []
        pending: dict[str, dict] = {}
        with self.path.open() as f:
            for line in f:
                if not line.strip():
                    continue
                ev = json.loads(line)
                if ev["event"] == "submit":
                    pending[ev["rid"]] = ev
                elif ev["event"] == "finish":
                    pending.pop(ev["rid"], None)
        return list(pending.values())
