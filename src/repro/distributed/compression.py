"""Gradient compression for the data-parallel all-reduce (beyond-paper).

int8 block-quantized all-reduce with error feedback: each leaf is quantized
per-block (block = trailing dim rows) to int8 with an f32 scale, summed
across data-parallel replicas, dequantized, and the quantization residual is
carried to the next step (error feedback keeps convergence unbiased in
practice). Wire bytes drop ~4× for fp32 moments / 2× for bf16 grads; on the
2-pod mesh this shrinks the slow inter-pod all-reduce term (EXPERIMENTS.md
§Perf pod-axis iteration).

Pure-JAX: expressed with psum inside shard_map, or as a jit-level transform
``compressed_mean`` usable in the train step.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressionState:
    error: Any  # residual pytree (same structure as grads)


def init_state(grads_like) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization (rows = leading dims)."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, state: CompressionState):
    """Apply error feedback + int8 round-trip to a gradient pytree.

    Returns (compressed-view grads ready for the mean-reduce, new state).
    In a shard_map'd train step the int8 payload is what crosses the links;
    under jit+GSPMD this models the numerics while XLA still moves f32 — the
    bytes win is realized on the explicit-collective path (see
    distributed/collectives.py shard_map variant).
    """

    def one(g, e):
        x = g.astype(jnp.float32) + e
        if g.ndim == 0:
            return x, jnp.zeros_like(x)
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return deq, x - deq

    pairs = jax.tree.map(one, grads, state.error)
    out = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], jax.Array))
    err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2 and isinstance(t[0], jax.Array))
    return out, CompressionState(error=err)


def wire_bytes(grads, compressed: bool) -> int:
    """Bytes a DP all-reduce would move per replica."""
    total = 0
    for g in jax.tree.leaves(grads):
        if compressed and g.ndim > 0:
            rows = int(jnp.prod(jnp.asarray(g.shape[:-1]))) if g.ndim > 1 else 1
            total += g.size * 1 + rows * 4  # int8 payload + f32 scales
        else:
            total += g.size * g.dtype.itemsize
    return total
