"""Distributed substrate: sharding rules, checkpointing, elastic recovery,
gradient compression."""

from .checkpoint import CheckpointManager, RequestJournal
from .compression import CompressionState, compress_decompress, init_state, wire_bytes
from .elastic import ElasticPlan, build_mesh, plan_mesh, reshard
from .sharding import (
    batch_specs,
    cache_specs,
    make_shardings,
    moment_specs,
    param_specs,
)

__all__ = [
    "CheckpointManager",
    "CompressionState",
    "ElasticPlan",
    "RequestJournal",
    "batch_specs",
    "build_mesh",
    "cache_specs",
    "compress_decompress",
    "init_state",
    "make_shardings",
    "moment_specs",
    "param_specs",
    "plan_mesh",
    "reshard",
    "wire_bytes",
]
