"""Sharding rules: map every param / cache / batch array to a PartitionSpec.

Mesh axes: ``model`` = tensor/expert parallel; ``data`` (+ optional ``pod``)
= batch parallel. Rules are name-based over the param pytree and operate on
*trailing* dims (leading layer-stack / slot dims are never sharded). Any
sharding that does not divide the axis evenly is dropped (GQA kv-heads < TP
degree ⇒ replicated KV, etc.) so every (arch × mesh) cell lowers cleanly.

Optimizer moments additionally shard over the data axis on their largest
already-unsharded dim (ZeRO-1 style) so 42 B-param training states fit v5e.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# param-name → (trailing-dim sharding pattern); "col" shards the last dim on
# model, "row" shards the second-to-last, "embed" shards vocab (dim -2),
# "expert" shards a leading expert dim (ndim==3 stacks)
_COL = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_ck", "w_cr", "wg", "wr",
    "w_kv_b", "lm_head", "w_in", "w_gel", "w_a", "w_i",
}
_ROW = {"wo", "w_down", "w_cv", "w_out"}
_EMBED = {"embed"}
_REPL = {"router", "w_kv_a", "wa", "wb", "conv_w"}  # small / awkward dims


def _divides(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


_ATTN_Q = {"wq"}
_ATTN_KV = {"wk", "wv"}
_ATTN_O = {"wo"}


def spec_for_param(path: tuple, shape: tuple[int, ...], mesh: Mesh,
                   cfg=None) -> P:
    """PartitionSpec for one parameter leaf.

    ``cfg`` (ModelConfig) enables head-aware attention sharding: projections
    are only column/row-sharded over 'model' when whole heads divide the TP
    degree — otherwise GSPMD hits "involuntary full rematerialization" on
    the (S, H·hd) → (S, H, hd) reshape and replicates giant activations
    (§Perf iter-4). Sub-head-divisible projections are replicated instead
    (cheap: MQA/GQA K/V mats are small).
    """
    model = mesh.shape.get("model", 1)
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1] if names else ""
    # tuples (LoRA (A, B)) add a trailing index component
    if name in ("0", "1") and len(names) >= 2:
        name = names[-2]
    if cfg is not None and cfg.rwkv is None and cfg.mla is None:
        heads_ok = cfg.num_heads % model == 0
        kv_ok = cfg.num_kv_heads % model == 0
        if name in _ATTN_Q and not heads_ok:
            return P()
        if name in _ATTN_KV and not kv_ok:
            return P()
        if name in _ATTN_O and not heads_ok:
            return P()
    lora_stack = any(n in ("lora", "q", "k", "v", "o", "r", "kv_a") for n in names) and len(shape) == 4
    if lora_stack:
        # (L, slots, d_in, r) / (L, slots, r, d_out): replicate (small)
        return P()
    if name in _REPL or len(shape) <= 1:
        return P()
    # MoE expert stacks: (E, d, ff) etc — shard experts over model
    moe_stack = name in ("w_gate", "w_up", "w_down") and len(shape) >= 3
    if moe_stack:
        # possibly (L, E, a, b) after layer stacking
        e_dim = len(shape) - 3
        if _divides(shape[e_dim], model):
            spec = [None] * len(shape)
            spec[e_dim] = "model"
            return P(*spec)
        return P()
    if name in _EMBED:
        spec = [None] * len(shape)
        if _divides(shape[-2], model):
            spec[-2] = "model"
        return P(*spec)
    if name in _COL:
        spec = [None] * len(shape)
        if _divides(shape[-1], model):
            spec[-1] = "model"
        return P(*spec)
    if name in _ROW:
        spec = [None] * len(shape)
        if _divides(shape[-2], model):
            spec[-2] = "model"
        return P(*spec)
    return P()


def param_specs(params, mesh: Mesh, cfg=None):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_param(path, leaf.shape, mesh, cfg), params
    )


def moment_specs(params, mesh: Mesh, cfg=None):
    """Optimizer-moment specs: param spec + ZeRO-1 data sharding on the
    largest still-unsharded dim."""
    data = mesh.shape.get("data", 1)

    def one(path, leaf):
        base = spec_for_param(path, leaf.shape, mesh, cfg)
        import math

        if math.prod(leaf.shape) < (1 << 22):
            return base  # small leaf: ZeRO sharding buys nothing, costs reshards
        spec = list(base) + [None] * (len(leaf.shape) - len(base))
        # find largest unsharded dim divisible by data
        best, best_dim = -1, None
        for i, (s, cur) in enumerate(zip(leaf.shape, spec)):
            if cur is None and _divides(s, data) and s > best and s >= data:
                best, best_dim = s, i
        if best_dim is not None:
            spec[best_dim] = "data"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_axes(mesh: Mesh) -> tuple:
    """The composite batch axis: ('pod', 'data') on multi-pod meshes."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def spec_for_batch(path: tuple, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Inputs: shard the leading batch dim over pod×data when divisible."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1] if names else ""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    if name == "mrope_positions" and len(shape) == 3:
        # (3, B, S)
        if _divides(shape[1], nb):
            return P(None, ba, None)
        return P()
    if len(shape) >= 1 and _divides(shape[0], nb):
        return P(ba, *([None] * (len(shape) - 1)))
    return P()


def batch_specs(batch, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_batch(path, leaf.shape, mesh), batch
    )


def spec_for_cache(path: tuple, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Decode caches: (L, B, T, H, D)-family arrays shard B over pod×data and
    the head dim over model when divisible; recurrent states shard heads."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1] if names else ""
    ba = batch_axes(mesh)
    nb = 1
    for a in ba:
        nb *= mesh.shape[a]
    model = mesh.shape.get("model", 1)
    if name == "len":
        return P(ba) if _divides(shape[0], nb) else P()
    spec: list = [None] * len(shape)
    if len(shape) >= 2 and _divides(shape[1], nb):
        spec[1] = ba
    # (L,B,T,H,D): shard KV heads over model when divisible; otherwise shard
    # the TIME axis (decode context-parallelism — GQA/MQA kv-heads < TP
    # degree would replicate a 100+ GiB cache otherwise).
    if name in ("k", "v", "ck", "cv") and len(shape) == 5:
        if _divides(shape[3], model):
            spec[3] = "model"
        elif _divides(shape[2], model):
            spec[2] = "model"
    if name in ("k_scale", "v_scale") and len(shape) == 4:
        # mirror the k/v sharding choice: heads if divisible, else time
        if _divides(shape[3], model):
            spec[3] = "model"
        elif _divides(shape[2], model):
            spec[2] = "model"
    if name in ("latent", "krope") and len(shape) == 4 and _divides(shape[2], model):
        spec[2] = "model"  # MLA (L,B,T,C): shard time
    if name == "wkv" and len(shape) == 5 and _divides(shape[2], model):
        spec[2] = "model"  # RWKV state (L,B,H,N,N): shard heads
    return P(*spec)


def cache_specs(cache, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_cache(path, leaf.shape, mesh), cache
    )


def make_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
