"""Elastic scaling / failure recovery for the production mesh.

On a real multi-pod deployment the runtime detects failed hosts via
heartbeats; here we provide the mesh-rebuild + re-shard machinery that the
restart path uses, testable on CPU with a changed device count:

  1. ``survivors`` = devices still healthy (any subset with a factorable
     count);
  2. ``plan_mesh`` picks the largest (data, model) grid ≤ survivors subject
     to model-parallel divisibility of the architecture;
  3. params are restored from the latest checkpoint with the NEW mesh's
     shardings (CheckpointManager.restore(shardings=...)) — re-sharding is a
     device_put, no manual resharding code;
  4. the serving engine replays its RequestJournal.

Straggler mitigation lives in the swapper (hedged swap re-issue) and the
simulator (recompute fallback past ``straggler_timeout``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh


@dataclasses.dataclass
class ElasticPlan:
    data: int
    model: int
    dropped_devices: int

    @property
    def size(self) -> int:
        return self.data * self.model


def _divisors_desc(n: int) -> list[int]:
    return [d for d in range(n, 0, -1) if n % d == 0]


def plan_mesh(
    n_healthy: int,
    *,
    preferred_model: int = 16,
    model_divisor_of: Optional[int] = None,
) -> ElasticPlan:
    """Largest usable (data, model) grid from ``n_healthy`` devices.

    ``model_divisor_of`` constrains the model axis to divide e.g. the
    attention-head count so TP stays valid for the architecture.
    """
    best: Optional[ElasticPlan] = None
    for used in range(n_healthy, 0, -1):
        for model in _divisors_desc(used):
            if model > preferred_model:
                continue
            if model_divisor_of is not None and model_divisor_of % model != 0:
                continue
            data = used // model
            plan = ElasticPlan(data=data, model=model,
                               dropped_devices=n_healthy - used)
            if best is None or plan.size > best.size or (
                plan.size == best.size and plan.model > best.model
            ):
                best = plan
        if best is not None and best.size == used:
            break
    if best is None:
        raise RuntimeError("no feasible (data, model) plan for the surviving devices")
    return best


def build_mesh(plan: ElasticPlan, devices: Optional[Sequence] = None) -> Mesh:
    devices = list(devices or jax.devices())[: plan.size]
    import numpy as np

    arr = np.array(devices).reshape(plan.data, plan.model)
    return Mesh(arr, ("data", "model"))


def reshard(tree, old_mesh: Mesh, new_shardings):
    """Move a pytree onto a new mesh's shardings (gather + re-place)."""
    host = jax.tree.map(lambda x: jax.device_get(x), tree)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), host, new_shardings
    )
