"""Encoder–decoder LM (seamless-m4t backbone).

The modality frontend is a STUB per the assignment: ``encode`` consumes
precomputed frame embeddings (B, S_src, d_model) instead of raw audio. The
decoder is a standard causal transformer with cross-attention; its self-KV
is cache-managed like any decoder-only arch, and the cross-KV is computed
once per request at prefill (cacheable per encoder prefix).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import gqa_cached, gqa_full, init_gqa, sdpa
from .common import dense_init, embed_init, init_rms, lora_delta, rms_norm
from .ffn import dense_ffn, init_dense_ffn

Array = jax.Array


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _init_cross(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }


def _cross_kv(p, enc_out, cfg):
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (enc_out @ p["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    return k, v


def _cross_attend(p, x, ck, cv, cfg):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.num_heads, hd)
    mask = jnp.ones((B, S, ck.shape[1]), bool)
    out = sdpa(q, ck, cv, mask)
    return out.reshape(B, S, -1) @ p["wo"]


@dataclasses.dataclass
class EncDecLM:
    cfg: ModelConfig
    dtype: jnp.dtype = jnp.bfloat16
    remat: bool = False
    unroll: bool = False  # dry-run: python loop instead of lax.scan

    def _scan_layers(self, body, init, xs):
        if not self.unroll:
            return jax.lax.scan(body, init, xs)
        length = len(jax.tree.leaves(xs)[0])
        carry = init
        outs = []
        for i in range(length):
            carry, out = body(carry, jax.tree.map(lambda a: a[i], xs))
            outs.append(out)
        if outs and outs[0] is not None:
            stacked = jax.tree.map(lambda *o: jnp.stack(o), *outs)
        else:
            stacked = None
        return carry, stacked

    # ------------------------------------------------------------------ init
    def init_params(self, key) -> dict:
        cfg = self.cfg
        n_enc, n_dec = cfg.encoder_layers, cfg.num_layers
        keys = jax.random.split(key, n_enc + n_dec + 2)
        enc_layers = []
        for k in keys[:n_enc]:
            k1, k2 = jax.random.split(k)
            enc_layers.append({
                "attn": init_gqa(k1, cfg, self.dtype),
                "ffn": init_dense_ffn(k2, cfg.d_model, cfg.d_ff, self.dtype),
                "norm1": init_rms(cfg.d_model, self.dtype),
                "norm2": init_rms(cfg.d_model, self.dtype),
            })
        dec_layers = []
        for k in keys[n_enc : n_enc + n_dec]:
            k1, k2, k3 = jax.random.split(k, 3)
            dec_layers.append({
                "attn": init_gqa(k1, cfg, self.dtype),
                "cross": _init_cross(k2, cfg, self.dtype),
                "ffn": init_dense_ffn(k3, cfg.d_model, cfg.d_ff, self.dtype),
                "norm1": init_rms(cfg.d_model, self.dtype),
                "norm_c": init_rms(cfg.d_model, self.dtype),
                "norm2": init_rms(cfg.d_model, self.dtype),
            })
        return {
            "encoder": _stack(enc_layers),
            "decoder": _stack(dec_layers),
            "embed": embed_init(keys[-2], cfg.vocab_size, cfg.d_model, self.dtype),
            "lm_head": dense_init(keys[-1], cfg.d_model, cfg.vocab_size, self.dtype),
            "enc_norm": init_rms(cfg.d_model, self.dtype),
            "final_norm": init_rms(cfg.d_model, self.dtype),
        }

    def lora_dims(self):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        return {
            "q": (d, cfg.num_heads * hd),
            "k": (d, cfg.num_kv_heads * hd),
            "v": (d, cfg.num_kv_heads * hd),
            "o": (cfg.num_heads * hd, d),
        }

    def init_lora(self, key, n_slots: int) -> dict:
        cfg = self.cfg
        r = cfg.lora.rank
        out = {}
        for t, (din, dout) in self.lora_dims().items():
            key, ka, kb = jax.random.split(key, 3)
            a = (jax.random.normal(ka, (cfg.num_layers, n_slots, din, r), jnp.float32)
                 * (1.0 / din ** 0.5)).astype(self.dtype)
            b = jnp.zeros((cfg.num_layers, n_slots, r, dout), self.dtype)
            out[t] = (a, b)
        return out

    @property
    def lora_scale(self) -> float:
        return self.cfg.lora.alpha / self.cfg.lora.rank

    # ---------------------------------------------------------------- encode
    def encode(self, params, frames: Array) -> Array:
        """frames: (B, S_src, d_model) precomputed frontend embeddings."""
        cfg = self.cfg
        B, S, _ = frames.shape
        x = frames.astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

        def body(x, lp):
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            # bidirectional: full visibility mask
            hd = cfg.resolved_head_dim
            q = (h @ lp["attn"]["wq"]).reshape(B, S, cfg.num_heads, hd)
            k = (h @ lp["attn"]["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
            v = (h @ lp["attn"]["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
            from .common import apply_rope

            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            mask = jnp.ones((B, S, S), bool)
            o = sdpa(q, k, v, mask).reshape(B, S, -1) @ lp["attn"]["wo"]
            x = x + o
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            x = x + dense_ffn(lp["ffn"], h2, cfg.activation)
            return x, None

        x, _ = self._scan_layers(body, x, params["encoder"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ----------------------------------------------------------------- train
    def forward(self, params, frames, tokens, *, lora=None, adapter_ids=None):
        """Teacher-forcing decode over the full target sequence."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        lora = lora or {}

        def body(x, xs):
            lp, lsl = xs
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            mixed, _ = gqa_full(lp["attn"], h, positions, cfg, lora=lsl,
                                adapter_ids=adapter_ids, lora_scale=self.lora_scale)
            x = x + mixed
            hc = rms_norm(x, lp["norm_c"], cfg.norm_eps)
            ck, cv = _cross_kv(lp["cross"], enc_out, cfg)
            x = x + _cross_attend(lp["cross"], hc, ck, cv, cfg)
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            x = x + dense_ffn(lp["ffn"], h2, cfg.activation)
            return x, None

        x, _ = self._scan_layers(body, x, (params["decoder"], lora))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"], jnp.float32(0.0)

    # ---------------------------------------------------------------- caches
    def init_cache(self, batch: int, max_len: int, src_len: int) -> dict:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        L = cfg.num_layers
        return {
            "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), self.dtype),
            "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), self.dtype),
            "ck": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, hd), self.dtype),
            "cv": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, hd), self.dtype),
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params, frames, tokens, max_len: int, *, lora=None,
                adapter_ids=None):
        """Encode + seed cross-KV + decode-prefill the target prefix."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, S = tokens.shape
        cache = self.init_cache(B, max_len, enc_out.shape[1])

        def seed(lp):
            return _cross_kv(lp["cross"], enc_out, cfg)

        ck, cv = jax.vmap(seed)(params["decoder"])  # (L,B,T,H,D)
        cache["ck"], cache["cv"] = ck, cv
        return self.extend(params, cache, tokens, jnp.zeros((B,), jnp.int32),
                           lora=lora, adapter_ids=adapter_ids)

    def extend(self, params, cache, tokens, start, *, lora=None, adapter_ids=None):
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        lora = lora or {}
        clen = cache.pop("len")

        def body(x, xs):
            lp, lsl, lc = xs
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            mixed, (ck_new, cv_new) = gqa_cached(
                lp["attn"], h, start, lc["k"], lc["v"], cfg, lora=lsl,
                adapter_ids=adapter_ids, lora_scale=self.lora_scale)
            x = x + mixed
            hc = rms_norm(x, lp["norm_c"], cfg.norm_eps)
            x = x + _cross_attend(lp["cross"], hc, lc["ck"], lc["cv"], cfg)
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            x = x + dense_ffn(lp["ffn"], h2, cfg.activation)
            return x, {"k": ck_new, "v": cv_new, "ck": lc["ck"], "cv": lc["cv"]}

        x, new_cache = self._scan_layers(body, x, (params["decoder"], lora, cache))
        cache["len"] = clen
        new_cache["len"] = start + S
        x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
        return x @ params["lm_head"], new_cache

    def decode(self, params, cache, tokens, *, lora=None, adapter_ids=None):
        return self.extend(params, cache, tokens, cache["len"], lora=lora,
                           adapter_ids=adapter_ids)
