"""Recurrent mixers: RWKV-6 (Finch) time/channel mix and Griffin RG-LRU.

Both are *state-based* (O(1) per decode step, sub-quadratic prefill), which
is what makes their architectures eligible for the ``long_500k`` shape. For
FASTLIBRA these states are the "KV cache" analogue: a per-prefix state
snapshot is cached by the dependency tree (see ``repro/kvcache/state_cache``).

Simplifications vs. the reference implementations (recorded in DESIGN.md):
RWKV-6 uses the data-dependent decay LoRA (the Finch hallmark) but a static
token-shift lerp for r/k/v/g (full ddlerp omitted); Griffin's RG-LRU follows
the paper's equations with full dense input/recurrence gates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import activation, dense_init, lora_delta, rms_norm

Array = jax.Array


def _proj(x, w, lora, name, adapter_ids, scale):
    y = x @ w
    if lora is not None and name in lora and adapter_ids is not None:
        a, b = lora[name]
        y = y + lora_delta(x, a, b, adapter_ids, scale)
    return y


# ================================================================== RWKV-6
def init_rwkv_layer(key, cfg: ModelConfig, dtype) -> dict:
    r = cfg.rwkv
    if r is None:
        raise ValueError("init_rwkv_layer requires cfg.rwkv to be configured")
    d = cfg.d_model
    H, N = d // r.head_dim, r.head_dim
    ks = jax.random.split(key, 10)
    lerp = lambda k: (jax.random.uniform(k, (d,), jnp.float32) * 0.5).astype(dtype)
    return {
        # time mix
        "mu_r": lerp(ks[0]), "mu_k": lerp(ks[1]), "mu_v": lerp(ks[2]),
        "mu_g": lerp(ks[3]), "mu_w": lerp(ks[4]),
        "w0": jnp.full((d,), -6.0, dtype),  # base decay (≈ slow)
        "wa": dense_init(ks[5], d, r.decay_rank, dtype),
        "wb": dense_init(ks[6], r.decay_rank, d, dtype),
        "u": jnp.zeros((H, N), dtype),
        "wr": dense_init(ks[7], d, d, dtype),
        "wk": dense_init(ks[8], d, d, dtype),
        "wv": dense_init(ks[9], d, d, dtype),
        "wg": dense_init(jax.random.fold_in(key, 10), d, d, dtype),
        "wo": dense_init(jax.random.fold_in(key, 11), d, d, dtype),
        "ln_x": jnp.zeros((d,), dtype),
        # channel mix
        "mu_ck": lerp(jax.random.fold_in(key, 12)),
        "mu_cr": lerp(jax.random.fold_in(key, 13)),
        "w_ck": dense_init(jax.random.fold_in(key, 14), d, cfg.d_ff, dtype),
        "w_cv": dense_init(jax.random.fold_in(key, 15), cfg.d_ff, d, dtype),
        "w_cr": dense_init(jax.random.fold_in(key, 16), d, d, dtype),
    }


def rwkv_state_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    H, N = d // r.head_dim, r.head_dim
    return {
        "tm_x": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, N, N), jnp.float32),
        "cm_x": jnp.zeros((batch, d), dtype),
    }


def _last_real(x, state_x, token_mask):
    """Last unmasked token of each row (fallback: carried state) — the
    token-shift anchor for the next chunk under row-masked batch prefill.

    Row lengths are independent, so mixed batches (decode rows with a single
    real token next to chunk-length prefill rows) anchor correctly per row;
    pad steps inside the scans are identity updates on the carried state."""
    n_real = token_mask.sum(axis=1)  # (B,)
    idx = jnp.maximum(n_real - 1, 0)
    last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0, :]
    return jnp.where((n_real > 0)[:, None], last, state_x)


def rwkv_time_mix(p, x, state, cfg, lora, adapter_ids, lora_scale,
                  token_mask=None):
    r = cfg.rwkv
    B, S, d = x.shape
    H, N = d // r.head_dim, r.head_dim
    xprev = jnp.concatenate([state["tm_x"][:, None, :], x[:, :-1, :]], axis=1)
    mix = lambda mu: x + (xprev - x) * mu
    rr = _proj(mix(p["mu_r"]), p["wr"], lora, "r", adapter_ids, lora_scale)
    kk = _proj(mix(p["mu_k"]), p["wk"], lora, "k", adapter_ids, lora_scale)
    vv = _proj(mix(p["mu_v"]), p["wv"], lora, "v", adapter_ids, lora_scale)
    gg = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    # data-dependent decay (Finch): w_t = exp(-exp(w0 + lora_w(x_w)))
    xw = mix(p["mu_w"])
    w_log = p["w0"].astype(jnp.float32) + ((xw @ p["wa"]) @ p["wb"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))  # (B,S,d) in (0,1)
    rr = rr.reshape(B, S, H, N).astype(jnp.float32)
    kk = kk.reshape(B, S, H, N).astype(jnp.float32)
    vv = vv.reshape(B, S, H, N).astype(jnp.float32)
    w = w.reshape(B, S, H, N)
    u = p["u"].astype(jnp.float32)

    def step(S_state, inputs):
        r_t, k_t, v_t, w_t, m_t = inputs  # each (B,H,N) / decay (B,H,N)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", r_t, S_state + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S_state + kv
        if m_t is not None:  # masked (pad) steps leave the wkv state intact
            S_new = jnp.where(m_t[:, None, None, None], S_new, S_state)
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rr, kk, vv, w))  # (S,B,H,N)
    xs = xs + ((jnp.moveaxis(token_mask, 1, 0),)
               if token_mask is not None else (None,))
    S_final, ys = jax.lax.scan(step, state["wkv"], xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)  # (B,S,d)
    # per-head group norm
    y = y.reshape(B, S, H, N)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y), axis=-1, keepdims=True) + 1e-5)
    y = y.reshape(B, S, d) * (1.0 + p["ln_x"].astype(jnp.float32))
    y = (y * gg.astype(jnp.float32)).astype(x.dtype)
    out = _proj(y, p["wo"], lora, "o", adapter_ids, lora_scale)
    tm_x = (x[:, -1, :] if token_mask is None
            else _last_real(x, state["tm_x"], token_mask))
    new_state = {"tm_x": tm_x, "wkv": S_final, "cm_x": state["cm_x"]}
    return out, new_state


def rwkv_channel_mix(p, x, state, cfg, token_mask=None):
    xprev = jnp.concatenate([state["cm_x"][:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (xprev - x) * p["mu_ck"]
    xr = x + (xprev - x) * p["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ p["w_ck"]))
    out = jax.nn.sigmoid(xr @ p["w_cr"]) * (k @ p["w_cv"])
    new_state = dict(state)
    new_state["cm_x"] = (x[:, -1, :] if token_mask is None
                         else _last_real(x, state["cm_x"], token_mask))
    return out, new_state


# ================================================================== RG-LRU
def init_rglru_layer(key, cfg: ModelConfig, dtype) -> dict:
    g = cfg.rglru
    if g is None:
        raise ValueError("init_rglru_layer requires cfg.rglru to be configured")
    d = cfg.d_model
    w = g.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], d, w, dtype),
        "w_gel": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (g.conv_width, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(ks[3], w, w, dtype),
        "b_a": jnp.zeros((w,), dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        "b_i": jnp.zeros((w,), dtype),
        "lam": jnp.linspace(2.0, 5.0, w).astype(dtype),  # Λ: a = σ(Λ) near 1
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def rglru_state_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, g.conv_width - 1, w), dtype),
    }


def _causal_depthwise_conv(x: Array, w: Array, b: Array, carry: Array,
                           token_mask: Array | None = None):
    """x: (B,S,W); w: (cw,W) depthwise; carry: (B,cw-1,W) previous inputs.

    With ``token_mask``, the new carry is the conv window ending at each
    row's last real token (pads are trailing junk that must not leak into
    the next chunk's receptive field)."""
    cw = w.shape[0]
    xx = jnp.concatenate([carry, x], axis=1)  # (B, S+cw-1, W)
    out = sum(xx[:, i : i + x.shape[1], :] * w[i] for i in range(cw)) + b
    if cw <= 1:
        return out, carry
    if token_mask is None:
        return out, xx[:, -(cw - 1) :, :]
    # xx index j holds the input at position j-(cw-1); the window feeding the
    # step after the last real token (n_real-1) is xx[n_real .. n_real+cw-2]
    n_real = token_mask.sum(axis=1)  # (B,)
    idx = n_real[:, None] + jnp.arange(cw - 1)[None, :]
    return out, jnp.take_along_axis(xx, idx[:, :, None], axis=1)


def rglru_block(p, x, state, cfg: ModelConfig, token_mask=None):
    """Griffin recurrent block: (gelu gate) ⊙ RG-LRU(conv1d(W_in x)) → W_out.

    Uses an associative scan over time (parallel prefill) for the linear
    recurrence h_t = a_t ⊙ h_{t-1} + b_t.
    """
    g = cfg.rglru
    B, S, _ = x.shape
    gate = jax.nn.gelu(x @ p["w_gel"])
    u = x @ p["w_in"]
    u, conv_carry = _causal_depthwise_conv(u, p["conv_w"], p["conv_b"],
                                           state["conv"], token_mask)
    r = jax.nn.sigmoid(u @ p["w_a"] + p["b_a"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    log_a_base = -jax.nn.softplus(-p["lam"].astype(jnp.float32))  # log σ(Λ) < 0
    log_a = g.c_exponent * r * log_a_base  # (B,S,W)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 0.0, 1.0)) * (
        i * u.astype(jnp.float32)
    )
    if token_mask is not None:  # pad steps: identity recurrence (a=1, b=0)
        m3 = token_mask[:, :, None]
        a = jnp.where(m3, a, 1.0)
        b = jnp.where(m3, b, 0.0)
    # prepend carried state as a pseudo-step: h_0 via (a=1 on carry trick)
    a_all = jnp.concatenate([jnp.ones((B, 1, a.shape[-1]), a.dtype), a], axis=1)
    b_all = jnp.concatenate([state["h"][:, None, :], b], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, h_all = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h = h_all[:, 1:, :]  # (B,S,W)
    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_state = {"h": h_all[:, -1, :], "conv": conv_carry}
    return y, new_state
