"""Composable model zoo covering every assigned architecture family."""

from .encdec import EncDecLM
from .model import (
    TrainState,
    build_model,
    cross_entropy,
    make_decode_step,
    make_prefill_step,
    make_train_state,
    make_train_step,
)
from .transformer import LM

__all__ = [
    "EncDecLM",
    "LM",
    "TrainState",
    "build_model",
    "cross_entropy",
    "make_decode_step",
    "make_prefill_step",
    "make_train_state",
    "make_train_step",
]
