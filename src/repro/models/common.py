"""Shared neural building blocks (pure-functional JAX)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms(d: int, dtype=jnp.float32) -> Array:
    return jnp.zeros((d,), dtype)  # (1 + scale) parametrization


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# --------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: Array, positions: Array, theta: float, sections: tuple[int, int, int]
) -> Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, ..., seq) — temporal / height / width position ids. The
    head_dim/2 frequency slots are partitioned into 3 sections; section ``i``
    rotates by ``positions[i]``. With all three position streams equal this
    reduces exactly to standard RoPE (text-only case).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # build per-slot position selection
    sec = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (hd/2,) in {0,1,2}
    # positions: (3, B, S) -> select per slot: (B, S, hd/2)
    pos = jnp.take(positions, sec, axis=0)  # (hd/2, B, S) after take on axis0
    pos = jnp.moveaxis(pos, 0, -1)  # (B, S, hd/2)
    angles = pos.astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- inits
def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------- LoRA op
def lora_delta(
    x: Array,
    lora_a: Array,
    lora_b: Array,
    adapter_ids: Array,
    scale: float,
) -> Array:
    """Batched multi-LoRA application (SGMV semantics, jnp formulation).

    x:           (B, S, d_in)
    lora_a:      (n_slots, d_in, r)    stacked adapter A matrices
    lora_b:      (n_slots, r, d_out)   stacked adapter B matrices
    adapter_ids: (B,) int32            slot index per sequence; a NEGATIVE id
                                       marks a base-model row (Δ masked to 0)
    Returns      (B, S, d_out)         Δ = (x @ A_i) @ B_i · scale

    Base-model rows are how the engine computes a request's declared
    adapter-independent shared prefix (A-LoRA semantics): the row runs with
    the adapter inactive, so its KV is exactly reusable across adapters.

    This is the gather-einsum reference; ``repro.kernels.sgmv`` provides the
    TPU Pallas kernel with identical semantics (tested against this), and
    with ``kernel_backend="pallas"`` the models' projection sites skip this
    function entirely — ``repro.kernels.fused_sgmv`` computes base + delta in
    one pass over the activation tile (README.md §Kernels).
    """
    ids = jnp.maximum(adapter_ids, 0)  # clamp so the gather stays in range
    a = jnp.take(lora_a, ids, axis=0)  # (B, d_in, r)
    b = jnp.take(lora_b, ids, axis=0)  # (B, r, d_out)
    h = jnp.einsum("bsd,bdr->bsr", x, a)
    delta = jnp.einsum("bsr,bro->bso", h, b) * scale
    live = (adapter_ids >= 0).astype(delta.dtype)[:, None, None]
    return delta * live


def causal_mask(q_pos: Array, k_pos: Array, k_valid: Array | None = None) -> Array:
    """Boolean (..., q, k) mask: key visible iff k_pos <= q_pos (and valid)."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if k_valid is not None:
        m = jnp.logical_and(m, k_valid[..., None, :])
    return m


def window_mask(q_pos: Array, k_pos: Array, window: int) -> Array:
    m = causal_mask(q_pos, k_pos)
    return jnp.logical_and(m, k_pos[..., None, :] > q_pos[..., :, None] - window)
