"""Composable decoder-only LM covering the dense / MoE / MLA / SSM / hybrid
/ VLM families. One code path, mixer and FFN chosen by config; homogeneous
stacks run under ``lax.scan`` (small HLO, fast multi-pod compiles), the
hybrid (RecurrentGemma) pattern unrolls a python loop over grouped stacks.

Public surface (all pure functions of params):
  init_params(key)                         -> params pytree
  init_lora(key, n_slots)                  -> stacked multi-LoRA params
  init_cache(batch, max_len)               -> decode cache pytree
  forward(params, tokens, ...)             -> (logits, aux)       train path
  prefill(params, tokens, max_len, ...)    -> (logits, cache)     fresh prefill
  extend(params, cache, tokens, start,...) -> (logits, cache)     chunked prefill
  decode(params, cache, tokens, ...)       -> (logits, cache)     1-token step
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import gqa_cached, gqa_full, init_gqa, init_mla, mla_cached, mla_full
from .common import dense_init, embed_init, init_rms, rms_norm
from .ffn import dense_ffn, init_dense_ffn, init_moe, moe_ffn
from .recurrent import (
    init_rglru_layer,
    init_rwkv_layer,
    rglru_block,
    rglru_state_init,
    rwkv_channel_mix,
    rwkv_state_init,
    rwkv_time_mix,
)

Array = jax.Array


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _index(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    dtype: jnp.dtype = jnp.bfloat16
    remat: bool = False
    # unroll=True replaces lax.scan over layers with a python loop. Needed by
    # the dry-run: XLA's cost_analysis counts a scan body ONCE (not × trip
    # count), so rooflines must be derived from the unrolled HLO.
    unroll: bool = False
    # §Perf knobs: q_chunk>0 enables blockwise (memory-efficient) attention;
    # remat_policy "dots" saves matmul outputs (recompute only cheap ops).
    q_chunk: int = 0
    remat_policy: str = "full"
    kv_quant: bool = False  # int8 KV cache (decode memory-roofline, §Perf)

    def _scan_layers(self, body, init, xs):
        if not self.unroll:
            return jax.lax.scan(body, init, xs)
        length = len(jax.tree.leaves(xs)[0]) if jax.tree.leaves(xs) else self.cfg.num_layers
        carry = init
        outs = []
        for i in range(length):
            carry, out = body(carry, _index(xs, i))
            outs.append(out)
        if outs and outs[0] is not None:
            stacked = jax.tree.map(lambda *o: jnp.stack(o), *outs)
        else:
            stacked = None
        return carry, stacked

    # ------------------------------------------------------------------ init
    def init_params(self, key) -> dict:
        cfg = self.cfg
        kemb, khead, *kl = jax.random.split(key, 2 + cfg.num_layers)
        params: dict = {
            "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, self.dtype),
            "final_norm": init_rms(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(khead, cfg.d_model, cfg.vocab_size, self.dtype)
        if cfg.rglru is not None:
            params.update(self._init_hybrid_layers(kl))
        else:
            params["layers"] = _stack([self._init_layer(k) for k in kl])
        return params

    def _init_layer(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        p = {"norm1": init_rms(cfg.d_model, self.dtype),
             "norm2": init_rms(cfg.d_model, self.dtype)}
        if cfg.rwkv is not None:
            p["mixer"] = init_rwkv_layer(k1, cfg, self.dtype)
            return p  # rwkv carries its own channel-mix (no separate ffn)
        if cfg.mla is not None:
            p["mixer"] = init_mla(k1, cfg, self.dtype)
        else:
            p["mixer"] = init_gqa(k1, cfg, self.dtype)
        if cfg.moe is not None:
            p["ffn"] = init_moe(k2, cfg, self.dtype)
        else:
            p["ffn"] = init_dense_ffn(k2, cfg.d_model, cfg.d_ff, self.dtype)
        return p

    def _layer_types(self) -> list[str]:
        cfg = self.cfg
        pat = cfg.rglru.block_pattern
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]

    def _init_hybrid_layers(self, keys) -> dict:
        cfg = self.cfg
        types = self._layer_types()
        rec, attn, ffn, norms = [], [], [], []
        for t, k in zip(types, keys):
            k1, k2, k3 = jax.random.split(k, 3)
            if t == "rec":
                rec.append(init_rglru_layer(k1, cfg, self.dtype))
            else:
                attn.append(init_gqa(k1, cfg, self.dtype))
            ffn.append(init_dense_ffn(k2, cfg.d_model, cfg.d_ff, self.dtype))
            norms.append({"norm1": init_rms(cfg.d_model, self.dtype),
                          "norm2": init_rms(cfg.d_model, self.dtype)})
        return {
            "rec_layers": _stack(rec),
            "attn_layers": _stack(attn),
            "ffn_layers": _stack(ffn),
            "norms": _stack(norms),
        }

    # ------------------------------------------------------------------ LoRA
    def lora_dims(self) -> dict[str, tuple[int, int]]:
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        if cfg.rwkv is not None:
            dims = {"r": (d, d), "k": (d, d), "v": (d, d), "o": (d, d)}
        elif cfg.mla is not None:
            m = cfg.mla
            dims = {
                "q": (d, cfg.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)),
                "kv_a": (d, m.kv_lora_rank + m.qk_rope_head_dim),
                "o": (cfg.num_heads * m.v_head_dim, d),
            }
        else:
            dims = {
                "q": (d, cfg.num_heads * hd),
                "k": (d, cfg.num_kv_heads * hd),
                "v": (d, cfg.num_kv_heads * hd),
                "o": (cfg.num_heads * hd, d),
            }
        return {t: dims[t] for t in cfg.lora.targets if t in dims}

    def init_lora(self, key, n_slots: int) -> dict:
        """Stacked multi-LoRA params: {target: (A:(L,slots,din,r), B:(L,slots,r,dout))}."""
        cfg = self.cfg
        r = cfg.lora.rank
        out = {}
        for t, (din, dout) in self.lora_dims().items():
            key, ka, kb = jax.random.split(key, 3)
            a = (jax.random.normal(ka, (cfg.num_layers, n_slots, din, r), jnp.float32)
                 * (1.0 / din ** 0.5)).astype(self.dtype)
            b = jnp.zeros((cfg.num_layers, n_slots, r, dout), self.dtype)
            out[t] = (a, b)
        return out

    @property
    def lora_scale(self) -> float:
        return self.cfg.lora.alpha / self.cfg.lora.rank

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        L = cfg.num_layers
        if cfg.rwkv is not None:
            st = rwkv_state_init(cfg, batch, self.dtype)
            cache = {k: jnp.stack([v] * L) for k, v in st.items()}
        elif cfg.rglru is not None:
            types = self._layer_types()
            n_rec = types.count("rec")
            n_attn = types.count("attn")
            rst = rglru_state_init(cfg, batch, self.dtype)
            W = min(max_len, cfg.window_size or max_len)
            hd = cfg.resolved_head_dim
            cache = {
                "h": jnp.stack([rst["h"]] * n_rec),
                "conv": jnp.stack([rst["conv"]] * n_rec),
                "k": jnp.zeros((n_attn, batch, W, cfg.num_kv_heads, hd), self.dtype),
                "v": jnp.zeros((n_attn, batch, W, cfg.num_kv_heads, hd), self.dtype),
            }
        elif cfg.mla is not None:
            m = cfg.mla
            cache = {
                "latent": jnp.zeros((L, batch, max_len, m.kv_lora_rank), self.dtype),
                "krope": jnp.zeros((L, batch, max_len, m.qk_rope_head_dim), self.dtype),
            }
        else:
            hd = cfg.resolved_head_dim
            kv_dtype = jnp.int8 if self.kv_quant else self.dtype
            cache = {
                "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), kv_dtype),
                "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), kv_dtype),
            }
            if self.kv_quant:
                cache["k_scale"] = jnp.zeros(
                    (L, batch, max_len, cfg.num_kv_heads), jnp.float32)
                cache["v_scale"] = jnp.zeros(
                    (L, batch, max_len, cfg.num_kv_heads), jnp.float32)
        cache["len"] = jnp.zeros((batch,), jnp.int32)
        return cache

    # ------------------------------------------------------------ embeddings
    def _embed(self, params, tokens, extra_embeds=None):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.dtype)
        if extra_embeds is not None:
            x = x + extra_embeds.astype(self.dtype)  # modality-frontend stub
        return x

    def _unembed(self, params, x):
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["lm_head"]

    # ---------------------------------------------------------- layer bodies
    def _layer_full(self, lp, lora_slice, x, positions, adapter_ids,
                    mrope_positions, kv_out: bool):
        """One layer, full-sequence (train / fresh prefill)."""
        cfg = self.cfg
        aux = jnp.float32(0.0)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if cfg.rwkv is not None:
            st = rwkv_state_init(cfg, x.shape[0], self.dtype)
            mixed, st = rwkv_time_mix(lp["mixer"], h, st, cfg, lora_slice,
                                      adapter_ids, self.lora_scale)
            x = x + mixed
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            out, st = rwkv_channel_mix(lp["mixer"], h2, st, cfg)
            x = x + out
            return x, aux, (st if kv_out else None)
        if cfg.mla is not None:
            mixed, kv = mla_full(lp["mixer"], h, positions, cfg, lora=lora_slice,
                                 adapter_ids=adapter_ids, lora_scale=self.lora_scale)
        else:
            mixed, kv = gqa_full(lp["mixer"], h, positions, cfg, lora=lora_slice,
                                 adapter_ids=adapter_ids, lora_scale=self.lora_scale,
                                 window=self.cfg.window_size if self.cfg.rglru else 0,
                                 mrope_positions=mrope_positions,
                                 q_chunk=self.q_chunk)
        x = x + mixed
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, aux = moe_ffn(lp["ffn"], h2, cfg)
        else:
            out = dense_ffn(lp["ffn"], h2, cfg.activation)
        x = x + out
        return x, aux, (kv if kv_out else None)

    def _layer_cached(self, lp, lora_slice, lcache, x, start, adapter_ids,
                      mrope_positions, token_mask=None):
        """One layer against a cache (decode / chunked prefill)."""
        cfg = self.cfg
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if cfg.rwkv is not None:
            st = {k: lcache[k] for k in ("tm_x", "wkv", "cm_x")}
            mixed, st = rwkv_time_mix(lp["mixer"], h, st, cfg, lora_slice,
                                      adapter_ids, self.lora_scale,
                                      token_mask=token_mask)
            x = x + mixed
            h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
            out, st = rwkv_channel_mix(lp["mixer"], h2, st, cfg,
                                       token_mask=token_mask)
            x = x + out
            return x, st
        if cfg.mla is not None:
            mixed, (cl, ck) = mla_cached(
                lp["mixer"], h, start, lcache["latent"], lcache["krope"], cfg,
                lora=lora_slice, adapter_ids=adapter_ids, lora_scale=self.lora_scale,
                token_mask=token_mask)
            new_cache = {"latent": cl, "krope": ck}
        else:
            mixed, new_kv = gqa_cached(
                lp["mixer"], h, start, lcache["k"], lcache["v"], cfg,
                lora=lora_slice, adapter_ids=adapter_ids, lora_scale=self.lora_scale,
                window=self.cfg.window_size if self.cfg.rglru else 0,
                mrope_positions=mrope_positions,
                cache_k_scale=lcache.get("k_scale"),
                cache_v_scale=lcache.get("v_scale"),
                token_mask=token_mask)
            if len(new_kv) == 4:
                new_cache = {"k": new_kv[0], "v": new_kv[1],
                             "k_scale": new_kv[2], "v_scale": new_kv[3]}
            else:
                new_cache = {"k": new_kv[0], "v": new_kv[1]}
        x = x + mixed
        h2 = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            out, _ = moe_ffn(lp["ffn"], h2, cfg)
        else:
            out = dense_ffn(lp["ffn"], h2, cfg.activation)
        x = x + out
        return x, new_cache

    # ================================================================ train
    def forward(self, params, tokens, *, lora=None, adapter_ids=None,
                extra_embeds=None, mrope_positions=None):
        """Full causal forward; returns (logits, moe_aux)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens, extra_embeds)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        if cfg.rglru is not None:
            x, aux = self._hybrid_full(params, x, positions, lora, adapter_ids)
        else:
            lora = lora or {}

            def body(carry, xs):
                x, aux = carry
                lp, lsl = xs
                fn = self._layer_full
                if self.remat:
                    policy = None
                    if self.remat_policy == "dots":
                        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                    fn = jax.checkpoint(
                        functools.partial(self._layer_full, kv_out=False),
                        policy=policy,
                    )
                    x, a, _ = fn(lp, lsl, x, positions, adapter_ids, mrope_positions)
                else:
                    x, a, _ = fn(lp, lsl, x, positions, adapter_ids,
                                 mrope_positions, kv_out=False)
                return (x, aux + a), None

            (x, aux), _ = self._scan_layers(body, (x, jnp.float32(0.0)),
                                            (params["layers"], lora))
        return self._unembed(params, x), aux

    def _hybrid_full(self, params, x, positions, lora, adapter_ids):
        cfg = self.cfg
        types = self._layer_types()
        ri = ai = 0
        aux = jnp.float32(0.0)
        for i, t in enumerate(types):
            norms = _index(params["norms"], i)
            h = rms_norm(x, norms["norm1"], cfg.norm_eps)
            if t == "rec":
                lp = _index(params["rec_layers"], ri)
                st = rglru_state_init(cfg, x.shape[0], self.dtype)
                mixed, _ = rglru_block(lp, h, st, cfg)
                ri += 1
            else:
                lp = _index(params["attn_layers"], ai)
                lsl = _index(lora, i) if lora else {}
                mixed, _ = gqa_full(lp, h, positions, cfg, lora=lsl,
                                    adapter_ids=adapter_ids,
                                    lora_scale=self.lora_scale,
                                    window=cfg.window_size,
                                    q_chunk=self.q_chunk)
                ai += 1
            x = x + mixed
            fp = _index(params["ffn_layers"], i)
            h2 = rms_norm(x, norms["norm2"], cfg.norm_eps)
            x = x + dense_ffn(fp, h2, cfg.activation)
        return x, aux

    # ============================================================== prefill
    def prefill(self, params, tokens, max_len: int, *, lora=None,
                adapter_ids=None, extra_embeds=None, mrope_positions=None):
        """Fresh full prefill: returns (last-token logits, seeded cache)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens, extra_embeds)
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        if cfg.rglru is not None:
            logits, cache = self._hybrid_cached(
                params, self.init_cache(B, max_len), x,
                jnp.zeros((B,), jnp.int32), lora, adapter_ids)
            cache["len"] = jnp.full((B,), S, jnp.int32)
            return logits, cache
        lora = lora or {}
        if cfg.rwkv is not None:
            def body(x, xs):
                lp, lsl = xs
                st0 = rwkv_state_init(cfg, B, self.dtype)
                xx, _, st = self._layer_full(lp, lsl, x, positions, adapter_ids,
                                             None, kv_out=True)
                return xx, st
            x, states = self._scan_layers(body, x, (params["layers"], lora))
            cache = dict(states)
            cache["len"] = jnp.full((B,), S, jnp.int32)
            return self._unembed(params, x[:, -1:, :]), cache

        def body(x, xs):
            lp, lsl = xs
            xx, _, kv = self._layer_full(lp, lsl, x, positions, adapter_ids,
                                         mrope_positions, kv_out=True)
            return xx, kv

        x, kvs = self._scan_layers(body, x, (params["layers"], lora))
        pad = max_len - S
        if cfg.mla is not None:
            latent, krope = kvs
            cache = {
                "latent": jnp.pad(latent, ((0, 0), (0, 0), (0, pad), (0, 0))),
                "krope": jnp.pad(krope, ((0, 0), (0, 0), (0, pad), (0, 0))),
            }
        else:
            k, v = kvs
            cache = {}
            if self.kv_quant:
                from .attention import quantize_kv_rows

                k, ks = quantize_kv_rows(k)
                v, vs = quantize_kv_rows(v)
                cache["k_scale"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0)))
                cache["v_scale"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0)))
            cache["k"] = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["len"] = jnp.full((B,), S, jnp.int32)
        return self._unembed(params, x[:, -1:, :]), cache

    # ====================================================== extend / decode
    def extend(self, params, cache, tokens, start, *, lora=None,
               adapter_ids=None, extra_embeds=None, mrope_positions=None,
               all_logits=False, true_lens=None):
        """Write ``tokens`` at per-row offsets ``start`` and return logits for
        the chunk (chunked prefill / decode are the S>1 / S=1 cases).

        ``true_lens`` (B,) enables row-masked batch prefill: row i's first
        ``true_lens[i]`` tokens are real, the rest pad to a shared (bucketed)
        shape. Pad positions neither write the cache nor advance recurrent
        state, and ``len`` advances by ``true_lens`` — so one jit-compiled
        shape serves every suffix length in the bucket.

        Per-row lengths are fully heterogeneous: a single call may mix
        prefill chunk rows (``true_lens == chunk``) with decode rows
        (``true_lens == 1``, the row's next token at column 0), which is the
        primitive the Sarathi-style mixed step scheduler
        (serving/scheduler.py) is built on. Each row's last-real-position
        logits are what callers should read (``all_logits=True`` + gather at
        ``true_lens - 1``)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = self._embed(params, tokens, extra_embeds)
        token_mask = None
        new_len = start + S
        if true_lens is not None:
            token_mask = jnp.arange(S)[None, :] < true_lens[:, None]
            new_len = start + true_lens
        if cfg.rglru is not None:
            logits, cache2 = self._hybrid_cached(params, cache, x, start, lora,
                                                 adapter_ids,
                                                 all_logits=all_logits,
                                                 token_mask=token_mask)
            cache2["len"] = new_len
            return logits, cache2
        lora = lora or {}
        clen = cache.pop("len")

        def body(x, xs):
            lp, lsl, lcache = xs
            xx, new_cache = self._layer_cached(lp, lsl, lcache, x, start,
                                               adapter_ids, mrope_positions,
                                               token_mask)
            return xx, new_cache

        x, new_cache = self._scan_layers(body, x, (params["layers"], lora, cache))
        cache["len"] = clen  # restore popped key on the input pytree
        new_cache["len"] = new_len
        out = x if all_logits else x[:, -1:, :]
        return self._unembed(params, out), new_cache

    def decode(self, params, cache, tokens, *, lora=None, adapter_ids=None,
               mrope_positions=None):
        """One-token decode step: tokens (B, 1); uses cache['len'] offsets."""
        return self.extend(params, cache, tokens, cache["len"], lora=lora,
                           adapter_ids=adapter_ids,
                           mrope_positions=mrope_positions)

    def _hybrid_cached(self, params, cache, x, start, lora, adapter_ids,
                       all_logits=False, token_mask=None):
        cfg = self.cfg
        types = self._layer_types()
        B, S, _ = x.shape
        positions = start[:, None] + jnp.arange(S)[None, :]
        ri = ai = 0
        new_h, new_conv, new_k, new_v = [], [], [], []
        for i, t in enumerate(types):
            norms = _index(params["norms"], i)
            h = rms_norm(x, norms["norm1"], cfg.norm_eps)
            if t == "rec":
                lp = _index(params["rec_layers"], ri)
                st = {"h": cache["h"][ri], "conv": cache["conv"][ri]}
                mixed, st = rglru_block(lp, h, st, cfg, token_mask=token_mask)
                new_h.append(st["h"])
                new_conv.append(st["conv"])
                ri += 1
            else:
                lp = _index(params["attn_layers"], ai)
                lsl = _index(lora, i) if lora else {}
                mixed, (ck, cv) = gqa_cached(
                    lp, h, start, cache["k"][ai], cache["v"][ai], cfg,
                    lora=lsl, adapter_ids=adapter_ids, lora_scale=self.lora_scale,
                    window=cfg.window_size, token_mask=token_mask)
                new_k.append(ck)
                new_v.append(cv)
                ai += 1
            x = x + mixed
            fp = _index(params["ffn_layers"], i)
            h2 = rms_norm(x, norms["norm2"], cfg.norm_eps)
            x = x + dense_ffn(fp, h2, cfg.activation)
        new_cache = {
            "h": jnp.stack(new_h),
            "conv": jnp.stack(new_conv),
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
            "len": cache["len"],
        }
        out = x if all_logits else x[:, -1:, :]
        return self._unembed(params, out), new_cache
