"""Model facade: build any assigned architecture and derive its step
functions (train / prefill / decode) — the objects the launcher lowers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..optim import OptState, adamw_init, adamw_update, clip_by_global_norm
from .encdec import EncDecLM
from .transformer import LM

Array = jax.Array


def build_model(cfg: ModelConfig, dtype=jnp.bfloat16, remat: bool = False,
                unroll: bool = False, q_chunk: int = 0,
                remat_policy: str = "full", kv_quant: bool = False,
                kernel_backend: str | None = None):
    if kernel_backend is not None:
        cfg = dataclasses.replace(cfg, kernel_backend=kernel_backend)
    if cfg.is_encdec:
        return EncDecLM(cfg, dtype, remat, unroll)
    return LM(cfg, dtype, remat, unroll, q_chunk, remat_policy,
              kv_quant=bool(kv_quant))


def cross_entropy(logits: Array, labels: Array, ignore: int = -1) -> Array:
    """Mean CE over valid positions; labels==ignore are masked.

    Written as logsumexp − one-hot contraction (no take_along_axis): a
    vocab-dim gather would force GSPMD to all-gather the (B,S,V) logits,
    while elementwise + reductions keep the vocab shard local (the unembed
    matmul shards V over 'model').
    """
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(safe, x.shape[-1], dtype=x.dtype)
    ll = jnp.sum(x * onehot, axis=-1)
    nll = lse - ll
    return jnp.sum(jnp.where(valid, nll, 0.0)) / jnp.maximum(1, jnp.sum(valid))


@dataclasses.dataclass
class TrainState:
    params: Any
    lora: Any
    opt: OptState
    step: Array


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "lora", "opt", "step"], meta_fields=[]
)


def make_train_step(
    model,
    *,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    moe_aux_weight: float = 0.01,
    train_lora_only: bool = False,
) -> Callable:
    """Builds the jit-able train step for any architecture.

    batch: {"tokens": (B,S) int32, "labels": (B,S) int32,
            "adapter_ids": (B,) int32, optional "frames"/"extra_embeds"}.
    """
    cfg = model.cfg

    def loss_fn(trainable, frozen, batch):
        params = frozen if train_lora_only else trainable["params"]
        lora = trainable.get("lora")
        if cfg.is_encdec:
            logits, aux = model.forward(params, batch["frames"], batch["tokens"],
                                        lora=lora, adapter_ids=batch.get("adapter_ids"))
        else:
            logits, aux = model.forward(
                params, batch["tokens"], lora=lora,
                adapter_ids=batch.get("adapter_ids"),
                extra_embeds=batch.get("extra_embeds"),
                mrope_positions=batch.get("mrope_positions"),
            )
        loss = cross_entropy(logits, batch["labels"])
        return loss + moe_aux_weight * aux, loss

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if train_lora_only:
            trainable = {"lora": state.lora}
            frozen = state.params
        else:
            trainable = {"params": state.params, "lora": state.lora}
            frozen = None
        (total, ce), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            trainable, frozen, batch
        )
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_trainable, new_opt = adamw_update(
            grads, state.opt, trainable, lr, weight_decay=weight_decay
        )
        new_state = TrainState(
            params=new_trainable.get("params", state.params),
            lora=new_trainable.get("lora", state.lora),
            opt=new_opt,
            step=state.step + 1,
        )
        return new_state, {"loss": ce, "grad_norm": gnorm}

    return train_step


def make_train_state(model, key, n_lora_slots: int = 0,
                     train_lora_only: bool = False) -> TrainState:
    k1, k2 = jax.random.split(key)
    params = model.init_params(k1)
    lora = model.init_lora(k2, n_lora_slots) if n_lora_slots else None
    if train_lora_only:
        opt = adamw_init({"lora": lora})
    else:
        opt = adamw_init({"params": params, "lora": lora})
    return TrainState(params=params, lora=lora, opt=opt,
                      step=jnp.zeros((), jnp.int32))


def make_prefill_step(model) -> Callable:
    cfg = model.cfg

    if cfg.is_encdec:
        def prefill_step(params, lora, batch):
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 max_len=batch["tokens"].shape[1],
                                 lora=lora, adapter_ids=batch.get("adapter_ids"))
    else:
        def prefill_step(params, lora, batch):
            return model.prefill(params, batch["tokens"],
                                 max_len=batch["tokens"].shape[1], lora=lora,
                                 adapter_ids=batch.get("adapter_ids"),
                                 extra_embeds=batch.get("extra_embeds"),
                                 mrope_positions=batch.get("mrope_positions"))

    return prefill_step


def make_decode_step(model) -> Callable:
    """serve_step: one new token against a seq_len KV cache."""

    def decode_step(params, lora, cache, batch):
        logits, cache = model.decode(params, cache, batch["tokens"], lora=lora,
                                     adapter_ids=batch.get("adapter_ids"))
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step
