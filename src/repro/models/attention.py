"""Attention variants: GQA/MQA (+qk-norm, sliding window, M-RoPE) and
DeepSeek multi-head latent attention (MLA), all cache-aware.

Conventions
-----------
* params are flat dicts of arrays for ONE layer; the transformer stacks them
  along a leading layer axis and slices inside ``lax.scan``.
* ``lora`` is an optional dict {target: (A, B)} with A:(slots,d_in,r),
  B:(slots,r,d_out); ``adapter_ids``:(B,) selects the slot per sequence
  (multi-LoRA batching — SGMV semantics).
* caches are dicts of arrays; decode writes one token per call at
  ``cache_len`` (B,) row offsets.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops as kernel_ops
from .common import (
    apply_mrope,
    apply_rope,
    causal_mask,
    dense_init,
    init_rms,
    lora_delta,
    rms_norm,
    window_mask,
)

Array = jax.Array


def _proj(x, w, lora, name, adapter_ids, scale, backend: str = "jnp"):
    """Base projection plus optional multi-LoRA delta.

    ``backend="pallas"`` routes LoRA-active projections through the fused
    SGMV kernel (``x·W + scale·(x·A)·B`` in one pass over the activation
    tile); otherwise — and whenever the projection has no adapter — it is a
    plain matmul with the gather-einsum ``lora_delta`` reference.
    """
    has_lora = lora is not None and name in lora and adapter_ids is not None
    if has_lora and backend == "pallas":
        a, b = lora[name]
        return kernel_ops.fused_sgmv(x, w, a, b, adapter_ids, scale=scale)
    y = x @ w
    if has_lora:
        a, b = lora[name]
        y = y + lora_delta(x, a, b, adapter_ids, scale)
    return y


def _page_size_for(T: int) -> int:
    """Largest preferred page size dividing the cache length."""
    for ps in (128, 64, 32, 16, 8):
        if T % ps == 0:
            return ps
    return 0  # no clean paging — caller falls back to ragged_extend


# =============================================================== GQA / MQA
def init_gqa(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd, dtype)
        p["k_norm"] = init_rms(hd, dtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions, lora, adapter_ids, lora_scale,
         mrope_positions=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    backend = cfg.kernel_backend
    q = _proj(x, p["wq"], lora, "q", adapter_ids, lora_scale, backend)
    k = _proj(x, p["wk"], lora, "k", adapter_ids, lora_scale, backend)
    v = _proj(x, p["wv"], lora, "v", adapter_ids, lora_scale, backend)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections is not None and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(q: Array, k: Array, v: Array, mask: Array, softcap: float = 0.0) -> Array:
    """GQA scaled-dot-product attention.

    q: (B,S,Hq,D)  k/v: (B,T,Hkv,D)  mask: (B,S,T) or (1,S,T) bool.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    q = q.reshape(B, S, Hkv, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(D))
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, Hq, v.shape[-1])  # v head dim may differ (MLA)


def sdpa_blockwise(
    q: Array,
    k: Array,
    v: Array,
    q_pos: Array,
    k_pos: Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> Array:
    """Memory-efficient causal attention (flash-style at the XLA level).

    Double-chunked online softmax: scans query chunks (outer ``lax.map``)
    and kv chunks (inner ``lax.scan`` carrying running max/sum/acc), so the
    live logits buffer is (B, Hkv, G, q_chunk, k_chunk) instead of the
    quadratic (…, S, S). This is the §Perf optimization that removes the
    memory-roofline blowup of naive attention at 32 k context (the Pallas
    ``flash_prefill`` kernel is the TPU-native version of the same tiling;
    this lowering keeps the dry-run pure-XLA).
    """
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    qc = min(q_chunk, S)
    kc = min(k_chunk, T)
    # pad S/T to chunk multiples (masked out via positions)
    pad_s = (-S) % qc
    pad_t = (-T) % kc
    qg = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0))).reshape(
        B, (S + pad_s) // qc, qc, Hkv, G, D
    )
    qp = jnp.pad(q_pos, ((0, 0), (0, pad_s)), constant_values=-1).reshape(
        B, (S + pad_s) // qc, qc
    )
    kk = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0))).reshape(
        B, (T + pad_t) // kc, kc, Hkv, D
    )
    vv = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0))).reshape(
        B, (T + pad_t) // kc, kc, Hkv, D
    )
    kp = jnp.pad(k_pos, ((0, 0), (0, pad_t)), constant_values=2**30).reshape(
        B, (T + pad_t) // kc, kc
    )

    def one_q_chunk(args):
        qb, qpb = args  # (B, qc, Hkv, G, D), (B, qc)

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, kpb = inp  # (B, kc, Hkv, D), (B, kc)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
            if softcap > 0:
                s = jnp.tanh(s / softcap) * softcap
            mask = kpb[:, None, None, None, :] <= qpb[:, None, None, :, None]
            if window > 0:
                mask = jnp.logical_and(
                    mask,
                    kpb[:, None, None, None, :]
                    > qpb[:, None, None, :, None] - window,
                )
            mask = jnp.logical_and(mask, kpb[:, None, None, None, :] >= 0)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p_, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p_, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kk, 1, 0), jnp.moveaxis(vv, 1, 0),
             jnp.moveaxis(kp, 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,qc,D)
        return jnp.moveaxis(out, 3, 1)  # (B,qc,Hkv,G,D)

    # checkpoint per q-chunk: without it AD saves every kv-step's chunk
    # logits and the backward materializes the full (S,S) again — the whole
    # point of blockwise attention is to recompute them chunkwise instead.
    outs = jax.lax.map(jax.checkpoint(one_q_chunk),
                       (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S + pad_s, Hq, D)[:, :S]
    return out.astype(q.dtype)


def gqa_full(
    p: dict,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    *,
    lora=None,
    adapter_ids=None,
    lora_scale: float = 1.0,
    window: int = 0,
    mrope_positions=None,
    q_chunk: int = 0,
) -> tuple[Array, tuple[Array, Array]]:
    """Full-sequence causal attention (train / fresh prefill).

    ``q_chunk > 0`` switches to the blockwise (memory-efficient) path.
    Returns (out, (k, v)) so callers can seed a decode cache.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, lora, adapter_ids, lora_scale,
                   mrope_positions)
    # the Pallas block-skip kernel implements plain causal-by-index
    # attention: positions here are always a fresh 0..S-1 arange (train /
    # fresh prefill), so index-causality == position-causality
    if (cfg.kernel_backend == "pallas" and q_chunk == 0 and window == 0
            and cfg.logit_softcap == 0.0):
        out = kernel_ops.flash_prefill(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
        ).transpose(0, 2, 1, 3)
    elif q_chunk > 0:
        out = sdpa_blockwise(q, k, v, positions, positions, window=window,
                             softcap=cfg.logit_softcap, q_chunk=q_chunk,
                             k_chunk=q_chunk)
    else:
        if window > 0:
            mask = window_mask(positions, positions, window)
        else:
            mask = causal_mask(positions, positions)
        out = sdpa(q, k, v, mask, cfg.logit_softcap)
    out = out.reshape(B, S, -1)
    out = _proj(out, p["wo"], lora, "o", adapter_ids, lora_scale,
                cfg.kernel_backend)
    return out, (k, v)


def quantize_kv_rows(x: Array) -> tuple[Array, Array]:
    """Per-(token, head) symmetric int8 quantization of K/V rows."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s.astype(jnp.float32)


def gqa_cached(
    p: dict,
    x: Array,
    start: Array,  # (B,) absolute position of x[:, 0]
    cache_k: Array,  # (B, T, Hkv, D) — bf16/f32, or int8 when quantized
    cache_v: Array,
    cfg: ModelConfig,
    *,
    lora=None,
    adapter_ids=None,
    lora_scale: float = 1.0,
    window: int = 0,
    mrope_positions=None,
    cache_k_scale: Array | None = None,  # (B, T, Hkv) — int8-KV mode
    cache_v_scale: Array | None = None,
    token_mask: Array | None = None,  # (B, S) bool — row-masked batch prefill
) -> tuple[Array, tuple]:
    """Suffix attention against a KV cache (decode S=1, or chunked prefill).

    Writes the new K/V at rows ``start..start+S`` (ring-indexed when
    ``window>0`` and T == window) and attends over the whole cache with a
    position-validity mask. With scale arrays present the cache is int8
    (§Perf: halves the decode memory-roofline vs bf16; dequant fuses into
    the attention dot so HBM traffic is the int8 payload).
    With ``token_mask`` (bucketed batch prefill: per-row suffixes padded to
    a shared length), masked positions keep the cache's existing contents —
    required for ring-indexed windows where a padded write would wrap onto
    live slots, and for rows that only ride along in the batch.
    Returns (out, updated cache arrays — (k, v) or (k, v, ks, vs)).
    """
    B, S, _ = x.shape
    T = cache_k.shape[1]
    quant = cache_k_scale is not None
    positions = start[:, None] + jnp.arange(S)[None, :]  # (B,S)
    q, k, v = _qkv(p, x, cfg, positions, lora, adapter_ids, lora_scale,
                   mrope_positions)
    if window > 0 and T == window:
        if token_mask is not None and S > window:
            # a padded chunk wider than the ring would scatter pad slots onto
            # this chunk's own real writes (duplicate indices, unspecified
            # winner) — callers must chunk to <= window first
            raise ValueError(
                f"row-masked chunk of {S} tokens exceeds ring window {window}")
        slots = positions % window
    else:
        slots = positions
    # scatter the new rows into the cache (per batch row)
    if token_mask is None:
        def write(c, new, slot):
            return c.at[slot].set(new)

        wmap = jax.vmap(write)
    else:
        def write(c, new, slot, m):
            keep = m.reshape((-1,) + (1,) * (new.ndim - 1))
            return c.at[slot].set(jnp.where(keep, new, c[slot]))

        wmap = lambda c, new, slot: jax.vmap(write)(c, new, slot, token_mask)

    if quant:
        kq, ks = quantize_kv_rows(k)
        vq, vs = quantize_kv_rows(v)
        cache_k = wmap(cache_k, kq, slots)
        cache_v = wmap(cache_v, vq, slots)
        cache_k_scale = wmap(cache_k_scale, ks, slots)
        cache_v_scale = wmap(cache_v_scale, vs, slots)
        k_eff = cache_k.astype(x.dtype) * cache_k_scale[..., None].astype(x.dtype)
        v_eff = cache_v.astype(x.dtype) * cache_v_scale[..., None].astype(x.dtype)
    else:
        cache_k = wmap(cache_k, k, slots)
        cache_v = wmap(cache_v, v, slots)
        k_eff, v_eff = cache_k, cache_v
    # absolute position of every cache slot, for masking. Under token_mask
    # the chunk's trailing positions are pads that wrote nothing: the ring
    # labeling and the validity frontier must anchor on each row's last REAL
    # position, or pad slots would shadow live window keys.
    if token_mask is None:
        last = positions[:, -1:]  # (B,1)
    else:
        n_real = token_mask.sum(axis=1)
        last = (start + jnp.maximum(n_real, 1) - 1)[:, None]
    # Pallas data plane (README.md §Kernels): plain causal GQA against the
    # dense cache goes through the length-trimmed kernels — paged decode for
    # single-token steps, ragged extend for (row-masked) suffix chunks.
    # Windowed/ring, int8-quantized and softcapped variants keep the einsum
    # path: those transforms live outside the kernels' contracts.
    use_pallas = (
        cfg.kernel_backend == "pallas"
        and window == 0
        and not quant
        and cfg.logit_softcap == 0.0
    )
    if use_pallas and S == 1 and token_mask is None:
        ps = _page_size_for(T)
        if ps > 0:
            # view the dense cache as contiguous pages and decode through
            # the paged kernel: lengths = start + 1 trims the page sweep
            pages = T // ps
            Hkv, Dh = k_eff.shape[2], k_eff.shape[3]
            tables = jnp.arange(B * pages, dtype=jnp.int32).reshape(B, pages)
            out = kernel_ops.paged_attention(
                q[:, 0],
                k_eff.reshape(B * pages, ps, Hkv, Dh),
                v_eff.reshape(B * pages, ps, Hkv, Dh),
                tables,
                (start + 1).astype(jnp.int32),
            )[:, None]
        else:
            out = kernel_ops.ragged_extend(
                q, k_eff, v_eff, start.astype(jnp.int32),
                jnp.ones((B,), jnp.int32),
            )
    elif use_pallas:
        if token_mask is None:
            true_lens = jnp.full((B,), S, jnp.int32)
        else:
            true_lens = token_mask.sum(axis=1).astype(jnp.int32)
        out = kernel_ops.ragged_extend(
            q, k_eff, v_eff, start.astype(jnp.int32), true_lens
        )
    else:
        if window > 0 and T == window:
            # slot j holds absolute position: largest p <= last with p % W == j
            j = jnp.arange(T)[None, :]
            kpos = last - ((last - j) % window)
        else:
            kpos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
        valid = jnp.logical_and(kpos <= last, kpos >= 0)
        if window > 0:
            mask = window_mask(positions, kpos, window)
            mask = jnp.logical_and(mask, valid[:, None, :])
        else:
            mask = causal_mask(positions, kpos, valid)
        out = sdpa(q, k_eff, v_eff, mask, cfg.logit_softcap)
    out = out.reshape(B, S, -1)
    out = _proj(out, p["wo"], lora, "o", adapter_ids, lora_scale,
                cfg.kernel_backend)
    if quant:
        return out, (cache_k, cache_v, cache_k_scale, cache_v_scale)
    return out, (cache_k, cache_v)


# ====================================================================== MLA
def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    if m is None:
        raise ValueError("init_mla requires cfg.mla to be configured")
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
        "w_kv_a": dense_init(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": init_rms(m.kv_lora_rank, dtype),
        "w_kv_b": dense_init(
            ks[2], m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim), dtype
        ),
        "wo": dense_init(ks[3], H * m.v_head_dim, d, dtype),
    }


def _mla_q(p, x, cfg, positions, lora, adapter_ids, lora_scale):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q = _proj(x, p["wq"], lora, "q", adapter_ids, lora_scale,
              cfg.kernel_backend)
    q = q.reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions, lora, adapter_ids, lora_scale):
    m = cfg.mla
    ckv = _proj(x, p["w_kv_a"], lora, "kv_a", adapter_ids, lora_scale,
                cfg.kernel_backend)
    latent, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    latent = rms_norm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return latent, k_rope


def mla_full(
    p: dict,
    x: Array,
    positions: Array,
    cfg: ModelConfig,
    *,
    lora=None,
    adapter_ids=None,
    lora_scale: float = 1.0,
    **_: object,
) -> tuple[Array, tuple[Array, Array]]:
    """Full-seq MLA (naive expanded form). Cache is (latent, k_rope)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions, lora, adapter_ids, lora_scale)
    latent, k_rope = _mla_latent(p, x, cfg, positions, lora, adapter_ids, lora_scale)
    kv = latent @ p["w_kv_b"]
    kv = kv.reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1,
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask = causal_mask(positions, positions)
    out = sdpa(q, k, v, mask)
    out = out.reshape(B, S, -1)
    out = _proj(out, p["wo"], lora, "o", adapter_ids, lora_scale,
                cfg.kernel_backend)
    return out, (latent, k_rope)


def mla_cached(
    p: dict,
    x: Array,
    start: Array,
    cache_latent: Array,  # (B, T, kv_lora)
    cache_krope: Array,  # (B, T, rope_dim)
    cfg: ModelConfig,
    *,
    lora=None,
    adapter_ids=None,
    lora_scale: float = 1.0,
    token_mask: Array | None = None,  # (B, S) bool — row-masked batch prefill
    **_: object,
) -> tuple[Array, tuple[Array, Array]]:
    """Cached MLA decode in the ABSORBED form.

    The up-projection ``w_kv_b`` is folded into the query/output sides so the
    per-step cost is O(S · kv_lora) instead of O(S · H · head_dim) — the
    compressed latent is attended directly (DeepSeek-V2 §"matrix absorption").
    """
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    T = cache_latent.shape[1]
    positions = start[:, None] + jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions, lora, adapter_ids, lora_scale)
    latent_new, krope_new = _mla_latent(
        p, x, cfg, positions, lora, adapter_ids, lora_scale
    )

    if token_mask is None:
        def write(c, new, slot):
            return c.at[slot].set(new)

        cache_latent = jax.vmap(write)(cache_latent, latent_new, positions)
        cache_krope = jax.vmap(write)(cache_krope, krope_new, positions)
    else:
        def write(c, new, slot, m):
            return c.at[slot].set(jnp.where(m[:, None], new, c[slot]))

        cache_latent = jax.vmap(write)(cache_latent, latent_new, positions,
                                       token_mask)
        cache_krope = jax.vmap(write)(cache_krope, krope_new, positions,
                                      token_mask)
    w_b = p["w_kv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_bk = w_b[..., : m.qk_nope_head_dim]  # (kv_lora, H, nope)
    w_bv = w_b[..., m.qk_nope_head_dim :]  # (kv_lora, H, v)
    # absorb: q_eff (B,S,H,kv_lora)
    q_eff = jnp.einsum("bshn,lhn->bshl", q_nope, w_bk)
    scores = jnp.einsum("bshl,btl->bhst", q_eff, cache_latent)
    scores = scores + jnp.einsum("bshr,btr->bhst", q_rope, cache_krope)
    scores = scores.astype(jnp.float32) / jnp.sqrt(
        jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim)
    )
    # validity frontier anchors on each row's last REAL position — rows in a
    # mixed batch have heterogeneous true lengths (a decode row's single
    # token rides in a chunk-sized bucket), and slots past the frontier hold
    # unwritten latents that must never enter the softmax
    if token_mask is None:
        last = positions[:, -1:]
    else:
        n_real = token_mask.sum(axis=1)
        last = (start + jnp.maximum(n_real, 1) - 1)[:, None]
    kpos = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    valid = kpos <= last
    mask = causal_mask(positions, kpos, valid)  # (B,S,T)
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btl->bshl", w, cache_latent)
    out = jnp.einsum("bshl,lhv->bshv", ctx, w_bv)
    out = out.reshape(B, S, -1)
    out = _proj(out, p["wo"], lora, "o", adapter_ids, lora_scale,
                cfg.kernel_backend)
    return out, (cache_latent, cache_krope)
