"""Feed-forward variants: gated dense (SwiGLU/GeGLU) and capacity-based MoE."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from .common import activation, dense_init

Array = jax.Array


# ------------------------------------------------------------------- dense
def init_dense_ffn(key, d: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, d_ff, dtype),
        "w_up": dense_init(ks[1], d, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d, dtype),
    }


def dense_ffn(p: dict, x: Array, act_name: str) -> Array:
    act = activation(act_name)
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


# --------------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    if m is None:
        raise ValueError("init_moe requires cfg.moe to be configured")
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_gate": dense_init(ks[1], d, ff, dtype)[None].repeat(E, 0),
        "w_up": dense_init(ks[2], d, ff, dtype)[None].repeat(E, 0),
        "w_down": dense_init(ks[3], ff, d, dtype)[None].repeat(E, 0),
    }
    if m.num_shared:
        p["shared"] = init_dense_ffn(ks[4], d, ff * m.num_shared, dtype)
    return p


def moe_ffn(
    p: dict, x: Array, cfg: ModelConfig, *, capacity: int | None = None
) -> tuple[Array, Array]:
    """Capacity-based top-k MoE (GShard/Switch-style dropping dispatch).

    Scatter-based dispatch avoids the (T, E, C) one-hot intermediate: each
    (token, k) pair computes its (expert, slot) destination and scatter-adds
    into the (E, C, d) buffer — memory is O(E·C·d) = O(T·k·cf·d), FLOPs are
    ~cf × the ideal active-expert FLOPs. Returns (out, aux_loss).
    """
    m = cfg.moe
    act = activation(cfg.activation)
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    E = m.num_experts
    C = capacity or max(1, int(T * m.top_k * m.capacity_factor / E))
    # position of each (token,k) inside its expert queue
    flat_e = expert_ids.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot  # running count per expert
    slot = jnp.sum(pos_in_e, axis=-1) - 1  # (T*k,)
    keep = slot < C
    slot = jnp.where(keep, slot, 0)
    tok_idx = jnp.repeat(jnp.arange(T), m.top_k)
    # dispatch: (E, C, d)
    disp = jnp.zeros((E, C, d), x.dtype)
    contrib = jnp.where(keep[:, None], xt[tok_idx], 0)
    disp = disp.at[flat_e, slot].add(contrib)
    # expert computation, batched over E
    h = act(jnp.einsum("ecd,edf->ecf", disp, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", disp, p["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, d)
    # combine: gather each (token,k) result and weight by its gate
    gathered = eout[flat_e, slot]  # (T*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[tok_idx].add(gathered * w)
    # shared experts (DeepSeek-style) always-on
    if "shared" in p:
        out = out + dense_ffn(p["shared"], xt, cfg.activation)
    # load-balancing aux loss (Switch):  E * Σ_e f_e · p_e
    density = jnp.mean(jax.nn.one_hot(expert_ids, E, dtype=jnp.float32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_prob)
    return out.reshape(B, S, d), aux
