"""Version-tolerant accessors for XLA compiled-executable analyses.

jaxlib < 0.4.36 returns ``cost_analysis()`` as a single dict (or a list with
one dict per partition on some backends); jaxlib >= 0.4.36 returns
``list[dict]`` everywhere, so the old ``(… or {}).get("flops", 0)`` idiom
crashes with ``AttributeError: 'list' object has no attribute 'get'``.
"""

from __future__ import annotations


def cost_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` to a plain dict across jaxlib
    versions (None → {}, list[dict] → first partition's dict)."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
