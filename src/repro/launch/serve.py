"""Serving launcher: run the multi-LoRA engine on any assigned architecture.

On this CPU container the engine serves the reduced config (full configs are
exercised via dryrun.py). On a TPU deployment the same entry point shards
params/caches over the production mesh with repro.distributed.sharding.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --variant fastlibra --requests 16
"""

from __future__ import annotations

import argparse
import random

import jax

from repro import configs
from repro.distributed import RequestJournal
from repro.serving import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=configs.ARCH_IDS)
    ap.add_argument("--variant", default="fastlibra")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--adapters", type=int, default=4)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture config (TPU-scale)")
    ap.add_argument("--journal", default="/tmp/repro_serve_journal.jsonl")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="arm libra-trace and dump Chrome trace-event JSON "
                         "here (load in Perfetto; see README §Observability)")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full_config:
        cfg = configs.reduced(cfg)
    # --trace-out arms the tracer explicitly; otherwise the EngineConfig
    # default picks up REPRO_TRACE=1
    ekw = {"trace": True} if args.trace_out else {}
    engine = ServingEngine(
        cfg,
        EngineConfig(hbm_bytes=8 << 20, host_bytes=64 << 20, block_size=4,
                     max_batch_slots=4, max_seq_len=128, variant=args.variant,
                     **ekw),
        key=jax.random.PRNGKey(args.seed),
    )
    for i in range(args.adapters):
        engine.register_adapter(f"lora-{i}")
    journal = RequestJournal(args.journal)

    # crash recovery: re-enqueue whatever a previous process left in flight
    for ev in journal.replay():
        engine.submit(Request(ev["rid"] + "-replayed", ev["adapter"],
                              tuple(ev["prompt"]), ev["max_new"]))
        print(f"replayed in-flight request {ev['rid']}")

    rng = random.Random(args.seed)
    for i in range(args.requests):
        rid = f"req-{i}"
        adapter = f"lora-{rng.randrange(args.adapters)}"
        prompt = tuple(rng.randrange(10, 200) for _ in range(rng.randint(6, 14)))
        journal.record_submit(rid, adapter, prompt, 6)
        engine.submit(Request(rid, adapter, prompt, max_new_tokens=6))
    report = engine.run()
    for r in engine.finished:
        journal.record_finish(r.request_id)
    print("report:", report.row())
    if args.trace_out:
        engine.export_trace(args.trace_out)
        print(f"trace: wrote {args.trace_out} "
              f"(summarize: python -m repro.obs.report {args.trace_out})")


if __name__ == "__main__":
    main()
