"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

No device allocation: params/optimizer/cache trees come from
``jax.eval_shape`` over the real init functions; batches are synthesized
directly. Modality frontends are stubs per the assignment: seamless gets
precomputed frame embeddings, qwen2-vl gets patch-embedding ``extra_embeds``
plus M-RoPE position ids.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import configs
from ..configs.base import ModelConfig, ShapeConfig
from ..models import build_model, make_train_state

SDS = jax.ShapeDtypeStruct


def batch_specs_for(cfg: ModelConfig, shape: ShapeConfig, n_lora: int = 8) -> dict:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind in ("decode", "long_decode"):
        batch = {
            "tokens": SDS((B, 1), jnp.int32),
            "adapter_ids": SDS((B,), jnp.int32),
        }
        if cfg.mrope_sections is not None:
            batch["mrope_positions"] = SDS((3, B, 1), jnp.int32)
        return batch
    batch = {
        "tokens": SDS((B, S), jnp.int32),
        "adapter_ids": SDS((B,), jnp.int32),
    }
    if kind == "train":
        batch["labels"] = SDS((B, S), jnp.int32)
    if cfg.is_encdec:
        batch["frames"] = SDS((B, S // 4, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["extra_embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = SDS((3, B, S), jnp.int32)
    return batch


def model_state_specs(cfg: ModelConfig, shape: ShapeConfig, n_lora: int = 8,
                      opts: dict | None = None):
    """eval_shape trees for params / lora / cache / train state as needed.
    ``opts``: §Perf knobs forwarded to build_model (q_chunk, remat_policy)."""
    opts = opts or {}
    model = build_model(cfg, dtype=jnp.bfloat16, remat=(shape.kind == "train"),
                        unroll=True, **opts)
    key = jax.random.PRNGKey(0)
    out: dict = {"model": model}
    if shape.kind == "train":
        out["train_state"] = jax.eval_shape(
            lambda k: make_train_state(model, k, n_lora_slots=n_lora), key
        )
        return out
    out["params"] = jax.eval_shape(model.init_params, key)
    out["lora"] = jax.eval_shape(lambda k: model.init_lora(k, n_lora), key)
    if shape.kind in ("decode", "long_decode"):
        B, S = shape.global_batch, shape.seq_len
        if cfg.is_encdec:
            out["cache"] = jax.eval_shape(
                lambda: model.init_cache(B, S, src_len=max(1, S // 4))
            )
        else:
            out["cache"] = jax.eval_shape(lambda: model.init_cache(B, S))
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference)."""
    n = cfg.active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
