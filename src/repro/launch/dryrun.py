import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "10")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and record roofline inputs.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices for the production
meshes. (Smoke tests / benches import repro normally and see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh pod1            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod1|pod2]
  PYTHONPATH=src python -m repro.launch.dryrun --list

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json with
  flops / bytes_accessed (per-device × chips), collective_bytes by op,
  memory_analysis, model_flops — consumed by benchmarks/roofline.py.
"""

import argparse
import json
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    make_shardings,
    moment_specs,
    param_specs,
)
from repro.launch.costs import cost_dict
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs_for, model_flops, model_state_specs
from repro.models import make_decode_step, make_prefill_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO result type like 'bf16[16,128,512]' (tuples summed)."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def entry_text(hlo_text: str) -> str:
    """The ENTRY computation only (nested fusion/while bodies excluded) —
    counting nested lines would double-count fused internals."""
    m = re.search(r"^ENTRY [^{]*\{", hlo_text, re.M)
    if not m:
        return hlo_text
    start = m.end()
    depth = 1
    i = start
    while i < len(hlo_text) and depth:
        c = hlo_text[i]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
        i += 1
    return hlo_text[start:i]


def hlo_bytes_by_op(hlo_text: str, top: int = 14) -> dict:
    """Result-shape bytes per op kind over the ENTRY computation.

    Approximates per-device HBM writes: each surviving top-level op's output
    is materialized once; fusion internals are excluded (they live in
    registers/VMEM on TPU). Backend note (EXPERIMENTS.md §Roofline): XLA
    *cost_analysis* on CPU additionally counts elementwise chains that a TPU
    compile would fuse — we record both and derive the memory term from the
    entry-only structural estimate.
    """
    per_op: dict[str, int] = {}
    for line in entry_text(hlo_text).splitlines():
        s = line.strip()
        m = re.search(r"=\s+(\([^)]*\)|\S+)\s+([a-z0-9-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        per_op[op] = per_op.get(op, 0) + _shape_bytes(m.group(1))
    return dict(sorted(per_op.items(), key=lambda kv: -kv[1])[:top])


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    compiled HLO. all-gather results count the full gathered size (what the
    links move per device, ring-style); all-reduce counts the operand once
    (reduce-scatter + all-gather of the same payload ≈ 2×, noted in
    EXPERIMENTS.md)."""
    per_op: dict[str, int] = {}
    for line in entry_text(hlo_text).splitlines():
        s = line.strip()
        # ROOT x = bf16[...] all-reduce(...) / x = (bf16[..], ..) all-to-all(..)
        m = re.search(r"=\s+(\([^)]*\)|\S+)\s+([a-z0-9-]+)", s)
        if not m:
            continue
        op = m.group(2)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES or op in _COLLECTIVES:
            base = op.replace("-start", "").replace("-done", "")
            if base not in _COLLECTIVES:
                continue
            if op.endswith("-done"):
                continue  # avoid double counting start/done pairs
            per_op[base] = per_op.get(base, 0) + _shape_bytes(m.group(1))
    per_op["total"] = sum(per_op.values())
    return per_op


def run_cell(arch: str, shape_name: str, mesh_name: str, verbose: bool = True,
             opts: dict | None = None) -> dict:
    shape = configs.get_shape(shape_name)
    cfg = configs.get(arch)
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    num_devices = mesh.size
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "num_devices": num_devices, "status": "ok",
        "model_flops": model_flops(cfg, shape),
        "opts": opts or {},
    }
    t0 = time.time()
    # head_aware=1 (§Perf iter-4): head-divisibility-aware attention sharding
    model_opts = dict(opts or {})
    shard_cfg = cfg if model_opts.pop("head_aware", 0) else None
    state = model_state_specs(cfg, shape, opts=model_opts)
    model = state["model"]
    batch = batch_specs_for(cfg, shape)
    with mesh:
        b_specs = make_shardings(batch_specs(batch, mesh), mesh)
        if shape.kind == "train":
            ts = state["train_state"]
            import dataclasses as _dc

            p_spec = param_specs(ts.params, mesh, shard_cfg)
            lora_spec = param_specs(ts.lora, mesh, shard_cfg) if ts.lora is not None else None
            opt_spec = type(ts.opt)(
                m=moment_specs(ts.opt.m, mesh, shard_cfg),
                v=moment_specs(ts.opt.v, mesh, shard_cfg),
                step=jax.sharding.PartitionSpec(),
            )
            from repro.models.model import TrainState

            ts_spec = TrainState(
                params=p_spec, lora=lora_spec, opt=opt_spec,
                step=jax.sharding.PartitionSpec(),
            )
            ts_shard = make_shardings(ts_spec, mesh)
            step = make_train_step(model)
            lowered = jax.jit(
                step, in_shardings=(ts_shard, b_specs)
            ).lower(ts, batch)
        elif shape.kind == "prefill":
            p_shard = make_shardings(param_specs(state["params"], mesh, shard_cfg), mesh)
            l_shard = make_shardings(param_specs(state["lora"], mesh, shard_cfg), mesh)
            step = make_prefill_step(model)
            lowered = jax.jit(
                step, in_shardings=(p_shard, l_shard, b_specs)
            ).lower(state["params"], state["lora"], batch)
        else:  # decode / long_decode
            p_shard = make_shardings(param_specs(state["params"], mesh, shard_cfg), mesh)
            l_shard = make_shardings(param_specs(state["lora"], mesh, shard_cfg), mesh)
            c_shard = make_shardings(cache_specs(state["cache"], mesh), mesh)
            step = make_decode_step(model)
            lowered = jax.jit(
                step, in_shardings=(p_shard, l_shard, c_shard, b_specs)
            ).lower(state["params"], state["lora"], state["cache"], batch)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ca = cost_dict(compiled)
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        rec["flops_per_device"] = flops_dev
        rec["bytes_per_device"] = bytes_dev
        rec["flops"] = flops_dev * num_devices
        rec["bytes_accessed"] = bytes_dev * num_devices
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            ):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec["collective_bytes_per_device"] = coll
        rec["collective_bytes"] = coll.get("total", 0)
        rec["bytes_by_op"] = hlo_bytes_by_op(hlo)
        # structural HBM-traffic floor: entry-level op outputs + one read of
        # every argument (params/caches). TPU-realistic; see docstring above.
        rec["bytes_entry_per_device"] = (
            sum(rec["bytes_by_op"].values())
            + rec.get("argument_size_in_bytes", 0)
        )
        if verbose:
            print(compiled.memory_analysis())  # proves the cell fits
            print({k: v for k, v in cost_dict(compiled).items()
                   if k in ("flops", "bytes accessed", "transcendentals")})
            print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
                  f"compile={rec['compile_s']:.1f}s "
                  f"flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e} "
                  f"coll/dev={coll.get('total',0):.3e}B")
            print(f"[dryrun]   memory: args={rec.get('argument_size_in_bytes',0)/2**30:.2f}GiB "
                  f"out={rec.get('output_size_in_bytes',0)/2**30:.2f}GiB "
                  f"temp={rec.get('temp_size_in_bytes',0)/2**30:.2f}GiB per device")
    return rec


def cell_path(arch: str, shape: str, mesh: str) -> pathlib.Path:
    return RESULTS / f"{arch}__{shape}__{mesh}.json"


def all_cells(mesh_filter=None):
    cells = []
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in configs.shape_cells(arch):
            for mesh in ("pod1", "pod2"):
                if mesh_filter and mesh != mesh_filter:
                    continue
                # cheapest-first: progress accumulates early, big/fragile
                # cells (MoE train) land last
                kind_cost = {"decode": 0, "long_decode": 1, "prefill": 2,
                             "train": 3}[shape.kind]
                cost = cfg.num_params() * (1 + kind_cost)
                cells.append((cost, arch, shape.name, mesh))
    cells.sort(key=lambda c: (c[0],))
    for _, arch, shape, mesh in cells:
        yield arch, shape, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("pod1", "pod2", "both"), default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="",
                    help="§Perf knobs, e.g. 'q_chunk=2048,remat_policy=dots'")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (optimized variants)")
    args = ap.parse_args()
    opts: dict = {}
    for kv in args.opt.split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        opts[k] = int(v) if v.lstrip("-").isdigit() else v
    RESULTS.mkdir(parents=True, exist_ok=True)
    if args.list:
        for cell in all_cells():
            done = cell_path(*cell).exists()
            print(("DONE " if done else "todo ") + "__".join(cell))
        return
    if args.all:
        mesh_filter = None if args.mesh == "both" else args.mesh
        cells = list(all_cells(mesh_filter))
        for arch, shape, mesh in cells:
            p = cell_path(arch, shape, mesh)
            if p.exists() and not args.force:
                continue
            try:
                rec = run_cell(arch, shape, mesh)
            except Exception as e:  # record failures as first-class results
                rec = {
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": f"error: {type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:],
                }
                print(f"[dryrun] FAILED {arch}×{shape}×{mesh}: {e}")
            p.write_text(json.dumps(rec, indent=1))
        return
    rec = run_cell(args.arch, args.shape, args.mesh, opts=opts)
    suffix = f"__{args.tag}" if args.tag else ""
    path = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
