"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = n_devices or len(jax.devices())
    if n % model != 0:
        raise ValueError(f"{n} devices not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"))
