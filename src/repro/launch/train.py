"""Training launcher with fault tolerance (checkpoint/restart, elastic mesh).

CPU container: trains the reduced config on a small device mesh (set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise real
multi-device sharding). On TPU the same code paths shard the full config
over the production mesh. Gradient compression (int8 + error feedback) is
available with --compress.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.distributed import (
    CheckpointManager,
    batch_specs,
    compress_decompress,
    init_state as compression_init,
    make_shardings,
    moment_specs,
    param_specs,
    plan_mesh,
    build_mesh,
)
from repro.models import build_model, make_train_state, make_train_step
from repro.models.model import TrainState


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=configs.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression on the DP all-reduce")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if not args.full_config:
        cfg = configs.reduced(cfg)
    model = build_model(cfg, dtype=jnp.float32)

    # elastic mesh over whatever devices this process sees
    plan = plan_mesh(len(jax.devices()), preferred_model=min(4, cfg.num_heads))
    mesh = build_mesh(plan)
    print(f"mesh: data={plan.data} model={plan.model} "
          f"(dropped {plan.dropped_devices} devices)")

    state = make_train_state(model, jax.random.PRNGKey(0), n_lora_slots=4)
    with mesh:
        ts_spec = TrainState(
            params=param_specs(state.params, mesh),
            lora=param_specs(state.lora, mesh),
            opt=type(state.opt)(
                m=moment_specs(state.opt.m, mesh),
                v=moment_specs(state.opt.v, mesh),
                step=jax.sharding.PartitionSpec(),
            ),
            step=jax.sharding.PartitionSpec(),
        )
        shardings = make_shardings(ts_spec, mesh)
        state = jax.device_put(state, shardings)
        base_step = make_train_step(model, lr=args.lr)
        if args.compress:
            comp_state = compression_init(
                {"params": state.params, "lora": state.lora}
            )
            print("gradient compression: int8 + error feedback enabled")

        step_fn = jax.jit(base_step, in_shardings=(shardings, None),
                          out_shardings=(shardings, None))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, jax.eval_shape(lambda: state), shardings)
            start = latest
            print(f"resumed from step {latest} (re-sharded onto current mesh)")

        t0 = time.time()
        for step in range(start, args.steps):
            k = jax.random.PRNGKey(step)
            batch = {
                "tokens": jax.random.randint(k, (args.batch, args.seq), 0,
                                             cfg.vocab_size),
                "labels": jax.random.randint(k, (args.batch, args.seq), 0,
                                             cfg.vocab_size),
                "adapter_ids": jnp.zeros((args.batch,), jnp.int32),
            }
            if cfg.is_encdec:
                batch["frames"] = jax.random.normal(
                    k, (args.batch, args.seq // 4, cfg.d_model))
            state, metrics = step_fn(state, batch)
            if (step + 1) % 10 == 0:
                dt = (time.time() - t0) / (step - start + 1)
                print(f"step {step+1:4d} loss={float(metrics['loss']):.4f} "
                      f"({dt*1e3:.0f} ms/step)")
            if (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, state)
        mgr.wait()
    print("training done")


if __name__ == "__main__":
    main()
