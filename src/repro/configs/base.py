"""Model / system configuration dataclasses and the architecture registry.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting a
``CONFIG`` built from these dataclasses; ``repro.configs.get(name)`` resolves
them (``--arch <id>`` in the launchers). ``reduced()`` derives the small
CPU-smoke variant of any config.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0  # per-expert hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = full-rank q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def kv_cache_dim(self) -> int:
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    ddlerp_rank: int = 32  # data-dependent token-shift low-rank
    decay_rank: int = 64


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0  # 0 = d_model
    conv_width: int = 4
    c_exponent: float = 8.0
    block_pattern: tuple[str, ...] = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 32
    alpha: float = 32.0
    targets: tuple[str, ...] = ("q", "k", "v", "o")
    max_adapters: int = 8  # resident simultaneously (HBM slot table)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 = d_model // num_heads
    activation: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: float = 0.0  # gemma-style
    window_size: int = 0  # sliding-window size for local attention layers
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rglru: Optional[RGLRUConfig] = None
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    # encoder-decoder (seamless): encoder depth; frontend embeddings replace
    # token embeddings on the encoder side (modality stub).
    encoder_layers: int = 0
    frontend: Optional[str] = None  # None | "audio" | "vision"
    # True if attention is sub-quadratic / state-based (long_500k eligible)
    subquadratic: bool = False
    # "jnp" (einsum correctness pin) or "pallas" (the kernels in
    # repro.kernels drive gqa_cached / gqa_full / LoRA projections; interpret
    # mode is auto-detected on CPU). The serving engine overrides this from
    # EngineConfig.kernel_backend; see README.md §Kernels.
    kernel_backend: str = "jnp"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Per-token KV-cache footprint — feeds the cache manager's block math."""
        if self.mla is not None:
            per_layer = self.mla.kv_cache_dim
        elif self.rwkv is not None:
            # state snapshot amortized per prefix node, not per token; use the
            # per-boundary snapshot size divided by the snapshot stride.
            hd = self.rwkv.head_dim
            heads = self.d_model // hd
            return (heads * hd * hd + 2 * self.d_model) * self.num_layers * dtype_bytes // 32
        else:
            per_layer = 2 * self.num_kv_heads * self.resolved_head_dim
        layers = self.num_layers
        if self.rglru is not None:
            pat = self.rglru.block_pattern
            attn_frac = pat.count("attn") / len(pat)
            layers = max(1, int(round(self.num_layers * attn_frac)))
        return per_layer * layers * dtype_bytes

    def num_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                d * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        elif self.rwkv is not None:
            attn = 6 * d * d  # r,k,v,g,o,w-ish
        else:
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.moe is not None:
            ffw = 3 * d * self.moe.d_ff_expert * (self.moe.num_experts + self.moe.num_shared)
            ffw += d * self.moe.num_experts  # router
        else:
            ffw = 3 * d * ff
        layers = L + self.encoder_layers
        return emb + layers * (attn + ffw)

    def active_params(self) -> int:
        """Activated parameters per token (MoE-aware) — for MODEL_FLOPS."""
        if self.moe is None:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = (
                d * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.num_heads * m.v_head_dim * d
            )
        else:
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        ffw = 3 * d * self.moe.d_ff_expert * (self.moe.top_k + self.moe.num_shared)
        ffw += d * self.moe.num_experts
        return emb + L * (attn + ffw)

    def lora_bytes(self, rank: int, dtype_bytes: int = 2) -> int:
        """Size of one adapter at ``rank`` over ``lora.targets``."""
        d = self.d_model
        hd = self.resolved_head_dim
        out_dims = {
            "q": self.num_heads * hd,
            "k": self.num_kv_heads * hd,
            "v": self.num_kv_heads * hd,
            "o": d,
        }
        layers = self.num_layers + self.encoder_layers
        total = 0
        for t in self.lora.targets:
            od = out_dims.get(t, d)
            total += rank * (d + od)
        return total * layers * dtype_bytes


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "long_decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


ARCH_IDS = (
    "gemma-2b",
    "stablelm-12b",
    "qwen3-4b",
    "qwen3-0.6b",
    "seamless-m4t-large-v2",
    "qwen2-vl-7b",
    "rwkv6-1.6b",
    "deepseek-v2-lite-16b",
    "phi3.5-moe-42b-a6.6b",
    "recurrentgemma-2b",
)

# paper's own base models (for the simulator benchmarks)
PAPER_ARCH_IDS = ("llama-7b", "llama-13b", "llama-34b")

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS + PAPER_ARCH_IDS}


def get(name: str) -> ModelConfig:
    """Resolve ``--arch <id>`` to its ModelConfig."""
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def shape_cells(arch: str) -> list[ShapeConfig]:
    """The (arch × shape) dry-run cells, with documented skips applied."""
    cfg = get(arch)
    out = []
    for s in LM_SHAPES:
        if s.kind == "long_decode" and not cfg.subquadratic:
            continue  # full-attention archs skip long_500k (DESIGN.md §4)
        out.append(s)
    return out


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw: dict = dict(
        name=cfg.name + "-reduced",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
    if cfg.mrope_sections is not None:
        half = kw["head_dim"] // 2
        a = half // 4
        kw["mrope_sections"] = (a, (half - a) // 2, half - a - (half - a) // 2)
    if cfg.moe is not None:
        # capacity_factor = E guarantees zero token drops (C == T·k) so the
        # smoke tests' prefill/forward parity is exact.
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2, num_shared=min(1, cfg.moe.num_shared),
            d_ff_expert=32, capacity_factor=4.0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_dim=16, ddlerp_rank=8, decay_rank=16)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=64, conv_width=4)
        kw["num_layers"] = 3  # one full (rec, rec, attn) group
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    if cfg.window_size:
        kw["window_size"] = 16
    kw["lora"] = LoRAConfig(rank=4, targets=cfg.lora.targets, max_adapters=4)
    return dataclasses.replace(cfg, **kw)
