"""stablelm-12b — dense GQA decoder [hf:stabilityai/stablelm-2-12b]."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    activation="silu",
    rope_theta=10000.0,
    tie_embeddings=False,
    lora=LoRAConfig(rank=32),
)
