"""rwkv6-1.6b (Finch) — attention-free SSM with data-dependent decay
[arXiv:2404.05892]. Sub-quadratic: runs the long_500k shape."""
from .base import LoRAConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,       # d_model / rwkv.head_dim
    num_kv_heads=32,    # unused (attention-free)
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    activation="relu2",
    tie_embeddings=False,
    rwkv=RWKVConfig(head_dim=64, ddlerp_rank=32, decay_rank=64),
    subquadratic=True,
    lora=LoRAConfig(rank=32, targets=("r", "k", "v", "o")),
)
