"""qwen2-vl-7b — VLM backbone with M-RoPE [arXiv:2409.12191].

Backbone only: the vision tower is a STUB; input_specs() provides
precomputed patch embeddings merged into the token stream.
"""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation="silu",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),  # t/h/w sections of head_dim//2
    tie_embeddings=False,
    frontend="vision",
    lora=LoRAConfig(rank=32),
)
