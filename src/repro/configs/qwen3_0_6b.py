"""qwen3-0.6b — dense GQA decoder with qk-norm [hf:Qwen/Qwen3-0.6B]."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    activation="silu",
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=32),
)
