"""Architecture registry: one module per assigned architecture."""
from .base import (
    ARCH_IDS,
    LM_SHAPES,
    PAPER_ARCH_IDS,
    LoRAConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RWKVConfig,
    ShapeConfig,
    get,
    get_shape,
    reduced,
    shape_cells,
)

__all__ = [
    "ARCH_IDS", "LM_SHAPES", "PAPER_ARCH_IDS", "LoRAConfig", "MLAConfig",
    "ModelConfig", "MoEConfig", "RGLRUConfig", "RWKVConfig", "ShapeConfig",
    "get", "get_shape", "reduced", "shape_cells",
]
