"""gemma-2b — dense MQA decoder [arXiv:2403.08295]."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,  # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="gelu",  # GeGLU
    rope_theta=10000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=32),
)
