"""deepseek-v2-lite-16b — MoE with multi-head latent attention
[arXiv:2405.04434]. MLA kv_lora=512; 2 shared + 64 routed experts, top-6."""
from .base import LoRAConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MLA: shared latent, heads expand from kv_lora
    head_dim=128,
    d_ff=1408,  # expert hidden dim (spec)
    vocab_size=102400,
    activation="silu",
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_ff_expert=1408),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    lora=LoRAConfig(rank=32, targets=("q", "kv_a", "o")),
)
