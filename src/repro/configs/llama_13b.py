"""llama-13b — the paper's base model (simulator benchmarks)."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, head_dim=128, d_ff=13824,
    vocab_size=32000, activation="silu", tie_embeddings=False,
    lora=LoRAConfig(rank=32),
)
