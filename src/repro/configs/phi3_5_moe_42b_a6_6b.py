"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE, GQA
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from .base import LoRAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    activation="silu",
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_ff_expert=6400),
    lora=LoRAConfig(rank=32),
)
