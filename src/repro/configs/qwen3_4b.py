"""qwen3-4b — dense GQA decoder with qk-norm [hf:Qwen/Qwen3-4B]."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    activation="silu",
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    lora=LoRAConfig(rank=32),
)
