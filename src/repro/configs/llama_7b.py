"""llama-7b — the paper's base model (simulator benchmarks)."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11008,
    vocab_size=32000, activation="silu", tie_embeddings=False,
    lora=LoRAConfig(rank=32),
)
