"""seamless-m4t-large-v2 — enc-dec audio backbone [arXiv:2308.11596].

The modality frontend (speech encoder frontend) is a STUB: input_specs()
provides precomputed frame embeddings of shape (batch, src_len, d_model).
"""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,          # decoder
    encoder_layers=24,      # encoder
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    activation="silu",
    rope_theta=10000.0,
    tie_embeddings=False,
    frontend="audio",
    lora=LoRAConfig(rank=32),
)
