"""recurrentgemma-2b (Griffin) — RG-LRU + local attention 1:2
[arXiv:2402.19427]. Sub-quadratic: runs the long_500k shape."""
from .base import LoRAConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,  # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu",  # GeGLU
    rope_theta=10000.0,
    window_size=2048,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, conv_width=4,
                      block_pattern=("rec", "rec", "attn")),
    subquadratic=True,
    lora=LoRAConfig(rank=32),
)
