"""llama-34b — the paper's base model (simulator benchmarks)."""
from .base import LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-34b", family="dense", num_layers=48, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=22016,
    vocab_size=32000, activation="silu", tie_embeddings=False,
    lora=LoRAConfig(rank=32),
)
