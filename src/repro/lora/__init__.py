"""Multi-LoRA substrate: adapter store + batched application."""

from .adapter import AdapterStore, AdapterWeights

__all__ = ["AdapterStore", "AdapterWeights"]
