"""LoRA adapter store: host-resident adapter weights + HBM slot table.

The device keeps ``max_adapters`` stacked slots (the layout the model's
multi-LoRA batching consumes: A (L, slots, d_in, r), B (L, slots, r, d_out)).
The FASTLIBRA cache manager decides *which* adapters are HBM-resident; this
store performs the physical host→device loads (slot writes) and maintains
the adapter→slot mapping the scheduler uses to build ``adapter_ids``.

Rank-dimension block paging (§4.3): an adapter of rank r occupies
``ceil(r / rank_block)`` unified-pool blocks; because all other dims match
the KV layout the pool never fragments. Padding ranks up to the slot rank is
TPU-friendly (slots are uniform, the SGMV kernel sees a static shape).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class AdapterWeights:
    """One adapter's host-side weights per target: {t: (A, B)} numpy."""

    adapter_id: str
    rank: int
    weights: dict[str, tuple[np.ndarray, np.ndarray]]

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes + b.nbytes for a, b in self.weights.values())


class AdapterStore:
    def __init__(self, model, max_slots: int, key: Optional[jax.Array] = None):
        self.model = model
        self.max_slots = max_slots
        key = key if key is not None else jax.random.PRNGKey(0)
        # device slot table (zeros = identity / no-op adapter)
        self.slots = jax.tree.map(
            lambda x: jnp.zeros_like(x), model.init_lora(key, max_slots)
        )
        self._host: dict[str, AdapterWeights] = {}
        self._slot_of: dict[str, int] = {}
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ----------------------------------------------------------------- host
    def register(self, adapter_id: str, key: jax.Array, scale: float = 0.01) -> AdapterWeights:
        """Create (or load) adapter weights into host memory."""
        if adapter_id in self._host:
            return self._host[adapter_id]
        lora = self.model.init_lora(key, 1)
        weights = {}
        for t, (a, b) in lora.items():
            bkey = jax.random.fold_in(key, hash(t) % (1 << 30))
            bmat = jax.random.normal(bkey, b[:, 0].shape, jnp.float32) * scale
            weights[t] = (np.asarray(a[:, 0]), np.asarray(bmat, np.float32))
        aw = AdapterWeights(adapter_id, self.model.cfg.lora.rank, weights)
        self._host[adapter_id] = aw
        return aw

    def host_bytes(self, adapter_id: str) -> int:
        return self._host[adapter_id].nbytes

    # --------------------------------------------------------------- device
    def slot_of(self, adapter_id: str) -> Optional[int]:
        return self._slot_of.get(adapter_id)

    def load(self, adapter_id: str) -> int:
        """Ensure the adapter occupies a device slot; returns the slot."""
        if adapter_id in self._slot_of:
            return self._slot_of[adapter_id]
        if not self._free_slots:
            raise RuntimeError(
                "no free adapter slots — evict via CacheManager first"
            )
        slot = self._free_slots.pop()
        aw = self._host[adapter_id]
        new_slots = dict(self.slots)
        for t, (a, b) in aw.weights.items():
            A, B = new_slots[t]
            A = A.at[:, slot].set(jnp.asarray(a, A.dtype))
            B = B.at[:, slot].set(jnp.asarray(b, B.dtype))
            new_slots[t] = (A, B)
        self.slots = new_slots
        self._slot_of[adapter_id] = slot
        return slot

    def unload(self, adapter_id: str) -> None:
        slot = self._slot_of.pop(adapter_id, None)
        if slot is None:
            return
        new_slots = dict(self.slots)
        for t, (A, B) in new_slots.items():
            new_slots[t] = (A, B.at[:, slot].set(0.0))
        self.slots = new_slots
        self._free_slots.append(slot)

    @property
    def resident(self) -> list[str]:
        return list(self._slot_of)
