"""Minimal production optimizer substrate (no external deps).

AdamW with fp32 moments regardless of parameter dtype, global-norm clipping,
cosine/linear schedules, and a LoRA-only masking helper for adapter
fine-tuning (the paper's multi-LoRA setting).
"""

from .adamw import (
    OptState,
    adamw_init,
    adamw_update,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    lora_only_mask,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "apply_updates",
    "clip_by_global_norm",
    "cosine_schedule",
    "global_norm",
    "lora_only_mask",
]
