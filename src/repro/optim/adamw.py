"""AdamW in pure JAX (fp32 moments, decoupled weight decay)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    m: Any
    v: Any
    step: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    grads,
    state: OptState,
    params,
    lr: float | jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask=None,
):
    """Returns (new_params, new_state). ``mask`` (same pytree of bools)
    freezes leaves where False — used for LoRA-only fine-tuning."""
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32), grads, state.m
    )
    new_v = jax.tree.map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads,
        state.v,
    )

    def upd(p, m, v):
        delta = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    if mask is not None:
        sel = lambda keep, new, old: new if keep else old  # mask is static bools
        new_params = jax.tree.map(sel, mask, new_params, params)
        new_m = jax.tree.map(sel, mask, new_m, state.m)
        new_v = jax.tree.map(sel, mask, new_v, state.v)
    return new_params, OptState(m=new_m, v=new_v, step=step)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def lora_only_mask(params_with_lora, lora_key: str = "lora"):
    """Bool mask: True only under the ``lora`` subtree."""
    def walk(tree, in_lora):
        if isinstance(tree, dict):
            return {k: walk(v, in_lora or k == lora_key) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(walk(v, in_lora) for v in tree)
        return in_lora

    return walk(params_with_lora, False)
