"""Discrete-event serving simulator (paper-scale figure reproduction)."""

from .hardware import DeployedModel, NPUSpec
from .simulator import ServingSimulator, SimConfig, SimRequest, SimResult

__all__ = [
    "DeployedModel",
    "NPUSpec",
    "ServingSimulator",
    "SimConfig",
    "SimRequest",
    "SimResult",
]
