"""Accelerator timing model for the discrete-event simulator.

Constants follow the paper's platform (Table 1): NPU with 256 TFLOPS fp16 /
64 GB HBM per card, PCIe 4.0 ×16 host link, Arm host with 256 GB. The same
dataclass can be pointed at TPU v5e (197 TFLOP/s bf16, 16 GB, 819 GB/s) for
the roofline cross-checks.

Timing formulas (standard serving roofline):
  prefill:  t = max(2·N_active·T / (F·mfu),  attn flops)  — compute-bound
  decode:   t = max(weight+KV bytes / HBM_bw, 2·N_active·B / F) + overhead
Multi-card tensor parallelism divides FLOPs/bandwidth by ``cards`` and adds
a per-layer collective latency.
"""

from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig


@dataclasses.dataclass
class NPUSpec:
    flops_fp16: float = 256e12  # per card
    hbm_bytes: int = 64 * 1024**3  # per card
    hbm_bw: float = 1.6e12  # per card
    # effective host<->device copy bandwidth. Raw PCIe4 x16 is ~26 GB/s but
    # the paper's Fig. 12 cold-start magnitudes (~230 ms for ~0.3 GB KV
    # prefixes) imply ~2 GB/s effective (unpinned torch.Tensor copies); we
    # calibrate to that so breakdowns are comparable (EXPERIMENTS.md §Fig12).
    pcie_bw: float = 2e9
    pcie_latency: float = 10e-6
    host_bytes: int = 256 * 1024**3
    prefill_mfu: float = 0.55
    decode_overhead: float = 0.004  # scheduler+dispatch per iteration (s)
    tp_collective_latency: float = 15e-6  # per layer per iteration
    dtype_bytes: int = 2


@dataclasses.dataclass
class DeployedModel:
    cfg: ModelConfig
    cards: int = 1
    npu: NPUSpec = dataclasses.field(default_factory=NPUSpec)

    @property
    def param_bytes(self) -> int:
        return self.cfg.num_params() * self.npu.dtype_bytes

    @property
    def active_param_bytes(self) -> int:
        return self.cfg.active_params() * self.npu.dtype_bytes

    @property
    def kv_bytes_per_token(self) -> int:
        return self.cfg.kv_bytes_per_token(self.npu.dtype_bytes)

    @property
    def is_recurrent(self) -> bool:
        return self.cfg.rwkv is not None or self.cfg.rglru is not None

    @property
    def state_snapshot_bytes(self) -> int:
        """Bytes of one full-model recurrent-state snapshot (0 for attention
        archs) at the deployment dtype — the STATE-node payload size."""
        if not self.is_recurrent:
            return 0
        from ..kvcache.state_cache import state_floats

        return state_floats(self.cfg) * self.npu.dtype_bytes

    def hbm_pool_bytes(self, activation_reserve: float = 0.1) -> int:
        """HBM available for the unified LoRA+KV pool after weights."""
        total = self.npu.hbm_bytes * self.cards
        reserve = int(total * activation_reserve)
        pool = total - self.param_bytes - reserve
        if pool <= 0:
            raise ValueError(
                f"{self.cfg.name} does not fit on {self.cards} card(s)"
            )
        return pool

    # ----------------------------------------------------------------- time
    def prefill_time(self, new_tokens: int, ctx_tokens: int) -> float:
        """Compute time to prefill ``new_tokens`` given ``ctx_tokens`` of
        already-cached context (attention still spans the full context)."""
        if new_tokens <= 0:
            return 0.0
        n = self.cfg.active_params()
        flops = 2.0 * n * new_tokens
        # causal attention over the full context
        d = self.cfg.d_model
        flops += 4.0 * d * new_tokens * (ctx_tokens + new_tokens / 2)
        f = self.npu.flops_fp16 * self.cards * self.npu.prefill_mfu
        t = flops / f
        t += self.cfg.num_layers * self.npu.tp_collective_latency * (self.cards > 1)
        return t

    def decode_time(self, batch: int, total_ctx_tokens: int) -> float:
        """One decode iteration for ``batch`` sequences with a combined
        context of ``total_ctx_tokens`` tokens."""
        if batch <= 0:
            return 0.0
        bw = self.npu.hbm_bw * self.cards
        f = self.npu.flops_fp16 * self.cards
        mem = (self.active_param_bytes + total_ctx_tokens * self.kv_bytes_per_token) / bw
        comp = 2.0 * self.cfg.active_params() * batch / f
        t = max(mem, comp) + self.npu.decode_overhead
        t += self.cfg.num_layers * self.npu.tp_collective_latency * (self.cards > 1)
        return t

    def transfer_time(self, nbytes: int) -> float:
        return self.npu.pcie_latency + nbytes / self.npu.pcie_bw
