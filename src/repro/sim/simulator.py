"""Discrete-event multi-LoRA serving simulator.

Drives the *real* FASTLIBRA control plane (``repro.core`` — the identical
code the JAX engine uses) with a virtual clock and the paper's NPU timing
model, so the paper's figures can be reproduced at Llama-7B/13B/34B scale on
a CPU container. The simulation is iteration-driven (like real continuous-
batching engines): each loop admits ready queries, runs one prefill+decode
iteration whose duration comes from :class:`DeployedModel`, and advances
virtual time.

Async swap modelling: host↔HBM transfers queue on full-duplex PCIe channels;
control-plane state flips instantly (the manager's view) but a query whose
required LoRA / KV nodes are still in flight cannot start prefill until its
``ready_time`` — this is exactly the cold-start component of TTFT the paper
measures (Fig. 12 breakdown).

Straggler mitigation (beyond-paper): if an inbound transfer would delay a
query past ``straggler_timeout``, the simulator falls back to recomputing
the prefix (hedged recompute) and counts the mitigation.
"""

from __future__ import annotations

import dataclasses
import heapq
import statistics
from collections import deque
from typing import Optional

from ..core import CacheManager, CacheSwapper, NodeKind, SwapKind, make_fastlibra
from ..core.cost_model import HardwareModel
from ..data.traces import SimQuery
from ..obs import (
    ATTRIB_CATEGORIES,
    EV_ADMIT,
    EV_CALIBRATION,
    EV_DECODE_STEP,
    EV_FINISH,
    EV_PREEMPT,
    EV_PREFILL_CHUNK,
    EV_QUEUE,
    EV_RESUME,
    EV_STEP,
    EV_SUBMIT,
    EV_TTFT_ATTRIBUTION,
    NULL_TRACER,
    TRACK_ENGINE,
    TRACK_QUEUE,
    Tracer,
    trace_env_enabled,
)
from .hardware import DeployedModel


@dataclasses.dataclass
class SimConfig:
    variant: str = "fastlibra"
    max_batch: int = 32
    block_size: int = 32
    lora_rank_choices: tuple[int, ...] = (32, 64)
    activation_reserve: float = 0.10
    straggler_p: float = 0.0  # probability a transfer is 10x slow
    straggler_timeout: float = 1.0
    sample_period: float = 5.0  # timeline sampling
    # step scheduling (mirrors EngineConfig.schedule_mode):
    # "alternate" — a ready prefill runs its whole suffix in one iteration
    #               (decode rides the same iteration but pays the full
    #               prefill latency: TPOT spikes under prefill load);
    # "mixed"     — Sarathi-style: decode tokens take 1 budget token each,
    #               prefill suffixes advance chunk-by-chunk with whatever
    #               budget remains, so iteration time stays bounded.
    schedule_mode: str = "alternate"
    step_token_budget: int = 512  # per-iteration token budget (mixed mode)
    # cross-adapter prefix sharing: cache declared adapter-independent spans
    # once on the shared trunk (False = per-adapter baseline)
    share_prefix_kv: bool = True
    # libra-trace parity: arm the same Tracer/event vocabulary the engine
    # uses (also armed by REPRO_TRACE=1, like EngineConfig.trace)
    trace: bool = dataclasses.field(default_factory=trace_env_enabled)
    trace_capacity: int = 200_000


@dataclasses.dataclass
class SimRequest:
    query: SimQuery
    ready_time: float = 0.0
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    matched_tokens: int = 0
    hbm_hit_tokens: int = 0
    lora_coldstart: float = 0.0
    kv_coldstart: float = 0.0
    queue_time: float = 0.0
    tokens_done: int = 0
    lookup: object = None
    pinned: list = dataclasses.field(default_factory=list)
    rid: str = ""
    # mixed-mode chunked prefill progress (suffix tokens already computed)
    prefill_done: int = 0
    # swap-preserving preemption: output tokens produced before a preemption
    # fold into the effective prompt (mirrors the engine's Request.carried),
    # so the resume lookup matches the victim's own demoted KV/state and
    # decode continues from token carried+1 — never recomputed divergently
    carried: int = 0
    preempt_count: int = 0
    # libra-trace TTFT attribution (mirrors serving.Request): an exact
    # additive partition of [arrival, first_token_time] on the VIRTUAL clock
    attribution: dict = dataclasses.field(default_factory=dict)
    attrib_cursor: Optional[float] = None
    ttft_predicted: Optional[float] = None

    def charge(self, category: str, t: float) -> None:
        """Attribute [attrib_cursor, t) to ``category`` and advance the
        cursor; closed once the first token lands (see Request.charge)."""
        if self.attrib_cursor is None or self.first_token_time is not None:
            return
        dt = t - self.attrib_cursor
        if dt > 0:
            self.attribution[category] = self.attribution.get(category, 0.0) + dt
            self.attrib_cursor = t

    @property
    def eff_prompt(self) -> tuple[int, ...]:
        """Prompt plus carried output tokens — what a resume prefills
        against (== query.prompt for a never-preempted request)."""
        q = self.query
        return q.full[: len(q.prompt) + self.carried]

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.query.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        return (self.finish_time - self.first_token_time) / max(
            1, self.query.output_len - 1
        )


@dataclasses.dataclass
class SimResult:
    finished: list[SimRequest]
    timeline: list[dict]
    duration: float
    manager: CacheManager
    straggler_mitigations: int = 0

    @property
    def avg_ttft(self) -> float:
        v = [r.ttft for r in self.finished if r.ttft is not None]
        return statistics.fmean(v) if v else 0.0

    @property
    def avg_tpot(self) -> float:
        v = [r.tpot for r in self.finished if r.tpot is not None]
        return statistics.fmean(v) if v else 0.0

    @property
    def avg_queue(self) -> float:
        v = [r.queue_time for r in self.finished]
        return statistics.fmean(v) if v else 0.0

    @property
    def avg_lora_coldstart(self) -> float:
        v = [r.lora_coldstart for r in self.finished]
        return statistics.fmean(v) if v else 0.0

    @property
    def avg_kv_coldstart(self) -> float:
        v = [r.kv_coldstart for r in self.finished]
        return statistics.fmean(v) if v else 0.0

    def summary(self) -> dict:
        s = self.manager.stats
        inv = [t["invalid_kv"] for t in self.timeline] or [0.0]
        hbm = [t["hbm_usage"] for t in self.timeline] or [0.0]
        return {
            "n": len(self.finished),
            "avg_ttft": self.avg_ttft,
            "avg_tpot": self.avg_tpot,
            "avg_queue": self.avg_queue,
            "avg_lora_cold": self.avg_lora_coldstart,
            "avg_kv_cold": self.avg_kv_coldstart,
            "kv_hit_rate": s.kv_hit_rate(),
            "state_hit_rate": s.state_hit_rate(),
            "lora_hit_rate": s.lora_hit_rate(),
            "avg_invalid_kv": statistics.fmean(inv),
            "avg_hbm_usage": statistics.fmean(hbm),
            "throughput": len(self.finished) / max(1e-9, self.duration),
        }


class ServingSimulator:
    def __init__(
        self,
        deployed: DeployedModel,
        trace: list[SimQuery],
        config: Optional[SimConfig] = None,
        seed: int = 0,
    ):
        import random

        self.cfg = config or SimConfig()
        self.hw = deployed
        self.trace = trace
        self.rng = random.Random(seed)
        pool_bytes = deployed.hbm_pool_bytes(self.cfg.activation_reserve)
        hw_model = HardwareModel(
            pcie_bw_bytes=deployed.npu.pcie_bw,
            pcie_latency_s=deployed.npu.pcie_latency,
            hbm_bytes=pool_bytes,
            host_bytes=deployed.npu.host_bytes,
            flops_fp16=deployed.npu.flops_fp16 * deployed.cards,
            # the recompute a retained snapshot saves, from the same roofline
            # that prices this model's prefill iterations
            prefill_s_per_token=deployed.prefill_time(1, 0),
        )
        # recurrent archs: the prefix layer is state snapshots, and TTFT is
        # snapshot-aware — a matched boundary shrinks the prefill suffix
        self._state_mode = deployed.is_recurrent
        self.tracer = (
            Tracer(capacity=self.cfg.trace_capacity)
            if self.cfg.trace else NULL_TRACER
        )
        self.manager, self.swapper = make_fastlibra(
            pool_bytes,
            deployed.npu.host_bytes,
            kv_bytes_per_token=deployed.kv_bytes_per_token,
            block_size=self.cfg.block_size,
            hardware=hw_model,
            variant=self.cfg.variant,
            state_bytes=deployed.state_snapshot_bytes,
            share_prefix_kv=self.cfg.share_prefix_kv,
            tracer=self.tracer,
        )
        # register every LoRA in the trace (host-resident at t=0)
        for lid in sorted({q.lora_id for q in trace}):
            rank = self.rng.choice(self.cfg.lora_rank_choices)
            nbytes = deployed.cfg.lora_bytes(rank, deployed.npu.dtype_bytes)
            self.manager.register_lora(lid, nbytes, now=0.0)
        # PCIe full-duplex channels: (free_at) per direction
        self._pcie_in = 0.0
        self._pcie_out = 0.0
        self._out_done = 0.0
        self._node_ready: dict[int, float] = {}
        self.straggler_mitigations = 0

    # ------------------------------------------------------------ transfers
    def _schedule_transfer(self, nbytes: int, now: float, inbound: bool) -> float:
        t = self.hw.transfer_time(nbytes)
        if self.cfg.straggler_p and self.rng.random() < self.cfg.straggler_p:
            t *= 10.0
        if inbound:
            start = max(now, self._pcie_in)
            self._pcie_in = start + t
            return self._pcie_in
        start = max(now, self._pcie_out)
        self._pcie_out = start + t
        return self._pcie_out

    def _execute_ops(self, ops, now: float) -> None:
        self._out_done = now
        for op in ops:
            if op.kind is SwapKind.SWAP_IN:
                done = self._schedule_transfer(op.nbytes, now, inbound=True)
                self._node_ready[op.node_id] = done
            elif op.kind is SwapKind.SWAP_OUT:
                self._out_done = max(
                    self._out_done,
                    self._schedule_transfer(op.nbytes, now, inbound=False),
                )

    # --------------------------------------------------------- SLO policy
    def _admission_rank(self, r: SimRequest, now: float):
        """Admission sort key (mirrors ``ServingEngine._admission_rank``):
        priority tier desc, then least deadline slack — the cost model's
        read-only TTFT estimate prices prefix recompute, host transfers,
        and adapter cold-start — then FCFS arrival, then rid."""
        q = r.query
        if q.deadline is None:
            slack = float("inf")
        else:
            est = self.manager.estimate_ttft(
                q.lora_id, r.eff_prompt[:-1],
                shared_prefix_len=q.shared_prefix_len)
            slack = q.deadline - now - est
        return (-q.priority, slack, q.arrival, r.rid)

    def _preempt(self, victim: SimRequest, now: float) -> None:
        """Swap-preserving preemption (mirrors ``ServingEngine._preempt``):
        the victim's computed prefix — everything up to its pending decode
        token — folds into the dependency tree (KV) or snapshots at that
        boundary (recurrent state), where the swapper demotes it to host
        under pressure instead of discarding it. Its produced tokens fold
        into the effective prompt (``carried``), so the resume lookup
        matches the demoted work and decode continues token-identically;
        the victim keeps its true first-token time."""
        q = victim.query
        boundary = len(q.prompt) + victim.tokens_done - 1
        if self._state_mode:
            self.manager.preempt_running(victim.rid, None, (), now)
            self.manager.commit_state(q.lora_id, q.full[:boundary], now)
        else:
            self.manager.preempt_running(
                victim.rid, victim.lookup, q.full[:boundary], now)
        self.manager.unpin(victim.pinned)
        self._execute_ops(self.manager.drain_ops(), now)
        victim.carried = victim.tokens_done
        victim.lookup = None
        victim.pinned = []
        victim.matched_tokens = 0
        victim.hbm_hit_tokens = 0
        victim.prefill_done = 0
        victim.preempt_count += 1
        if self.tracer.enabled:
            self.tracer.instant(
                TRACK_ENGINE, EV_PREEMPT, now,
                rid=victim.rid, folded=victim.tokens_done)

    def export_trace(self, path: str) -> None:
        """Dump the collected trace as Chrome trace-event JSON."""
        self.tracer.dump(path)

    # ------------------------------------------------------------ main loop
    def run(self) -> SimResult:
        cfg = self.cfg
        arrivals = [(q.arrival, i, q) for i, q in enumerate(self.trace)]
        heapq.heapify(arrivals)
        waiting: deque[SimRequest] = deque()
        pending: list[SimRequest] = []  # admitted, waiting on transfers
        running: list[SimRequest] = []
        finished: list[SimRequest] = []
        timeline: list[dict] = []
        now = 0.0
        next_sample = 0.0
        rid = 0
        # unified batch load: last iteration's real tokens (decode rows
        # contribute 1 each, prefill rows their chunk) — same signal the
        # engine feeds the swapper under the mixed scheduler
        batch_window: deque[tuple[float, int]] = deque()
        last_iter_tokens = 0

        recent_ttfts: deque[tuple[float, float]] = deque()

        def sample(now):
            while recent_ttfts and recent_ttfts[0][0] < now - cfg.sample_period:
                recent_ttfts.popleft()
            window = [v for _, v in recent_ttfts]
            bd = self.manager.hbm_breakdown()
            timeline.append({
                "t": now,
                "hbm_usage": self.manager.hbm_usage(),
                "invalid_kv": self.manager.invalid_kv_fraction(),
                "resident_loras": self.manager.tree.resident_lora_count(),
                "running": len(running),
                "waiting": len(waiting) + len(pending),
                "window_ttft": statistics.fmean(window) if window else 0.0,
                **bd,
            })

        while arrivals or waiting or pending or running:
            # pull arrivals
            while arrivals and arrivals[0][0] <= now:
                _, _, q = heapq.heappop(arrivals)
                rid += 1
                r = SimRequest(query=q, rid=f"q{rid}")
                r.attrib_cursor = q.arrival
                waiting.append(r)
                if self.tracer.enabled:
                    self.tracer.instant(
                        TRACK_QUEUE, EV_SUBMIT, q.arrival, rid=r.rid,
                        adapter=q.lora_id, prompt_tokens=len(q.prompt))
            # periodic swapper (proactive: transfers happen in the background,
            # off every query's critical path — FASTLIBRA's key advantage)
            if self.swapper.due(now):
                batch_window.append((now, last_iter_tokens))
                while batch_window and batch_window[0][0] < now - 5.0:
                    batch_window.popleft()
                if batch_window:
                    self.swapper.observe_batch_size(
                        sum(b for _, b in batch_window) / len(batch_window)
                    )
                self.swapper.tick(now)
                self._execute_ops(self.manager.drain_ops(), now)
            # admit — cost-ranked (priority tier, then least deadline slack,
            # then FCFS); a blocked higher-tier head may preempt a strictly
            # lower-priority running victim instead of waiting behind it
            while waiting:
                r = sorted(waiting,
                           key=lambda w: self._admission_rank(w, now))[0]
                q = r.query
                lk = adm = None
                blocked = len(running) + len(pending) >= cfg.max_batch
                if not blocked:
                    prompt = r.eff_prompt
                    if self.tracer.enabled and r.ttft_predicted is None:
                        # pre-lookup, so the estimate prices the cold start
                        # this admission is about to pay (calibration series)
                        r.ttft_predicted = self.manager.estimate_ttft(
                            q.lora_id, prompt[:-1],
                            shared_prefix_len=q.shared_prefix_len)
                    if self._state_mode:
                        lk = self.manager.lookup_state(
                            q.lora_id, prompt[:-1], now)
                        matched = lk.state_tokens
                    else:
                        lk = self.manager.lookup(
                            q.lora_id, prompt[:-1], now,
                            shared_prefix_len=q.shared_prefix_len)
                        matched = lk.match.matched_tokens
                    adm = self.manager.admit(lk, now)
                    if adm.queued:
                        self._execute_ops(self.manager.drain_ops(), now)
                        blocked = True
                if not blocked:
                    # lazy allocation (vLLM semantics): prefill blocks now,
                    # decode blocks one iteration at a time (stall when HBM
                    # is full). Recurrent state is O(1) per request: reserve
                    # one snapshot's blocks instead of phantom per-token KV.
                    if self._state_mode:
                        need = (self.manager.config.state_blocks
                                * self.cfg.block_size)
                    else:
                        need = len(prompt) - matched
                    blocks = self.manager.allocate_running(r.rid, need, now)
                    if blocks is None:
                        self.manager.unpin(adm.pinned)
                        self._execute_ops(self.manager.drain_ops(), now)
                        blocked = True
                if blocked:
                    victims = [v for v in running
                               if v.query.priority < q.priority]
                    if not victims:
                        break
                    victim = min(victims, key=lambda v: (
                        v.query.priority,
                        -(v.query.deadline if v.query.deadline is not None
                          else float("inf")),
                        -(v.admit_time if v.admit_time is not None else 0.0),
                        v.rid,
                    ))
                    running.remove(victim)
                    self._preempt(victim, now)
                    waiting.appendleft(victim)
                    continue
                waiting.remove(r)
                r.lookup = lk
                r.pinned = adm.pinned
                r.matched_tokens = matched
                r.hbm_hit_tokens = lk.hbm_hit_tokens
                r.admit_time = now
                r.queue_time = now - q.arrival
                qstart = r.attrib_cursor
                r.charge("queue", now)
                if self.tracer.enabled:
                    if qstart is not None and now > qstart:
                        self.tracer.span(
                            TRACK_QUEUE, EV_QUEUE, qstart, now, rid=r.rid)
                    self.tracer.instant(
                        TRACK_QUEUE,
                        EV_RESUME if r.preempt_count else EV_ADMIT, now,
                        rid=r.rid, adapter=q.lora_id, matched=matched,
                        hbm_hit=r.hbm_hit_tokens)
                # everything this admission moved — swap-ins of the needed
                # nodes AND demand-eviction swap-outs that freed its blocks —
                # is on this query's critical path (synchronous cold start)
                lora0, kv0 = r.lora_coldstart, r.kv_coldstart
                ops = self.manager.drain_ops()
                self._execute_ops(ops, now)
                ready = now
                for op in ops:
                    if op.kind is SwapKind.SWAP_IN:
                        done = self._node_ready.get(op.node_id, now)
                        if op.node_kind is NodeKind.LORA:
                            r.lora_coldstart += max(0.0, done - now)
                        else:
                            r.kv_coldstart += max(0.0, done - now)
                        ready = max(ready, done)
                    elif op.kind is SwapKind.SWAP_OUT:
                        done = self._out_done
                        r.kv_coldstart += max(0.0, done - now)
                        ready = max(ready, done)
                # also wait for matched nodes already in flight
                for n in lk.match.kv_nodes:
                    ready = max(ready, self._node_ready.get(n.node_id, now))
                if lk.match.lora_node is not None:
                    ready = max(
                        ready, self._node_ready.get(lk.match.lora_node.node_id, now)
                    )
                # straggler mitigation: recompute instead of waiting too long
                if ready - now > cfg.straggler_timeout:
                    self.straggler_mitigations += 1
                    r.matched_tokens = 0
                    r.hbm_hit_tokens = 0
                    ready = now
                if ready > now:
                    # the synchronous cold-start wait: split the wall time
                    # between lora_load and swap_in in proportion to the
                    # per-channel cold-start this admission accrued
                    dl = r.lora_coldstart - lora0
                    dk = r.kv_coldstart - kv0
                    if dl > 0:
                        frac = dl / (dl + dk) if (dl + dk) > 0 else 1.0
                        r.charge("lora_load", now + (ready - now) * frac)
                    r.charge("swap_in", ready)
                r.ready_time = ready
                r.prefill_done = 0
                pending.append(r)
            # build one iteration
            ready_prefills = [r for r in pending if r.ready_time <= now]
            if ready_prefills or running:
                t_iter = 0.0
                t_start = now
                entered: list[SimRequest] = []  # prefills completing now
                chunks: list[tuple[SimRequest, int]] = []  # (req, tokens)
                prefill_tokens = 0
                if cfg.schedule_mode == "mixed":
                    # Sarathi-style: decode tokens (1 per running request)
                    # come off the top of the budget; prefill suffixes
                    # advance chunk-by-chunk with the remainder, so one long
                    # prompt cannot blow up this iteration's duration
                    budget = max(cfg.step_token_budget - len(running), 1)
                    # interactive fast lane (mirrors plan_step fast_slots):
                    # higher tiers drain the budget first, FCFS within a tier
                    for r in sorted(ready_prefills,
                                    key=lambda r: (-r.query.priority,
                                                   r.query.arrival, r.rid)):
                        if budget <= 0:
                            break
                        left = (len(r.eff_prompt) - r.matched_tokens
                                - r.prefill_done)
                        take = min(left, budget)
                        t_iter += self.hw.prefill_time(
                            take, r.matched_tokens + r.prefill_done)
                        r.prefill_done += take
                        budget -= take
                        prefill_tokens += take
                        chunks.append((r, take))
                        if (r.prefill_done
                                >= len(r.eff_prompt) - r.matched_tokens):
                            entered.append(r)
                            pending.remove(r)
                else:
                    for r in ready_prefills:
                        pending.remove(r)
                        new = len(r.eff_prompt) - r.matched_tokens
                        t_iter += self.hw.prefill_time(new, r.matched_tokens)
                        prefill_tokens += new
                        chunks.append((r, new))
                        entered.append(r)
                ctx = sum(
                    len(r.query.prompt) + r.tokens_done for r in running
                )
                t_iter += self.hw.decode_time(len(running), ctx)
                last_iter_tokens = len(running) + prefill_tokens
                now += max(t_iter, 1e-6)
                # attribution: time a ready prefill sat past its ready_time
                # is "stall", its share of this iteration is "compute" —
                # charged before first_token_time closes the window below
                for r, take in chunks:
                    r.charge("stall", t_start)
                    r.charge("compute", now)
                if self.tracer.enabled:
                    for r, take in chunks:
                        self.tracer.span(
                            TRACK_ENGINE, EV_PREFILL_CHUNK, t_start, now,
                            rid=r.rid, tokens=take)
                    if running:
                        self.tracer.span(
                            TRACK_ENGINE, EV_DECODE_STEP, t_start, now,
                            rows=len(running))
                    self.tracer.span(
                        TRACK_ENGINE, EV_STEP, t_start, now,
                        tokens=last_iter_tokens)
                    self.tracer.counter(
                        "queue_depth", now,
                        waiting=float(len(waiting) + len(pending)))
                    self.tracer.counter(
                        "hbm_usage", now, frac=float(self.manager.hbm_usage()))
                for r in entered:
                    if r.first_token_time is None:
                        # a resumed preemption victim keeps its TRUE first-
                        # token time from before the preemption
                        r.first_token_time = now
                        recent_ttfts.append((now, r.ttft))
                    r.tokens_done = r.carried + 1
                    running.append(r)
                still = []
                any_progress = bool(entered) or prefill_tokens > 0
                stalled: list[SimRequest] = []
                for r in running:
                    if r in entered:
                        pass
                    else:
                        # decode KV growth is allocated lazily; a full pool
                        # stalls the request this iteration (TPOT grows).
                        # Recurrent decode consumes no extra memory.
                        got = ([] if self._state_mode else
                               self.manager.allocate_running(r.rid, 1, now))
                        if got is None:
                            stalled.append(r)
                            continue
                        r.tokens_done += 1
                        any_progress = True
                    if r.tokens_done >= r.query.output_len:
                        r.finish_time = now
                        if self._state_mode:
                            # fold a snapshot at the len(prompt)-1 boundary
                            # (mirrors the engine's capture point; for a
                            # resumed victim the boundary is its effective
                            # prompt's) instead of per-token KV; running
                            # blocks just release
                            self.manager.abort_running(r.rid)
                            self.manager.commit_state(
                                r.query.lora_id, r.eff_prompt[:-1], now)
                        else:
                            self.manager.commit(r.rid, r.lookup, r.query.full, now)
                        self.manager.unpin(r.pinned)
                        finished.append(r)
                        if self.tracer.enabled:
                            self.tracer.instant(
                                TRACK_ENGINE, EV_FINISH, now,
                                rid=r.rid, tokens=r.tokens_done)
                            if r.ttft is not None:
                                att = r.attribution
                                self.tracer.instant(
                                    TRACK_QUEUE, EV_TTFT_ATTRIBUTION, now,
                                    rid=r.rid, ttft=r.ttft,
                                    **{c: att.get(c, 0.0)
                                       for c in ATTRIB_CATEGORIES})
                            if (r.ttft_predicted is not None
                                    and r.ttft is not None):
                                self.tracer.instant(
                                    TRACK_QUEUE, EV_CALIBRATION, now,
                                    rid=r.rid, predicted=r.ttft_predicted,
                                    actual=r.ttft)
                    else:
                        still.append(r)
                # decode-growth evictions transfer in the background
                self._execute_ops(self.manager.drain_ops(), now)
                if stalled and not any_progress:
                    # every running request is blocked on HBM: preempt the
                    # lowest tier's youngest to unblock (rid tiebreak:
                    # simultaneous arrivals in trace bursts must preempt
                    # deterministically, not by list-build order) — swap-
                    # preserving, not vLLM recompute-preemption: its computed
                    # prefix demotes through the two-tier pool and it resumes
                    # token-identically with its first-token time intact
                    victim = max(stalled, key=lambda r: (
                        -r.query.priority, r.query.arrival, r.rid))
                    stalled.remove(victim)
                    self._preempt(victim, now)
                    waiting.appendleft(victim)
                running = still + stalled
            else:
                # idle: jump to the next event; the batch-load signal decays
                # to zero (nothing ran this iteration) instead of freezing
                # at the last busy token count
                last_iter_tokens = 0
                nxt = []
                if arrivals:
                    nxt.append(arrivals[0][0])
                if pending:
                    nxt.append(min(r.ready_time for r in pending))
                if waiting:
                    nxt.append(now + self.swapper.config.monitor_interval)
                if not nxt:
                    break
                now = max(now + 1e-6, min(nxt))
            if now >= next_sample:
                sample(now)
                next_sample = now + cfg.sample_period
        sample(now)
        return SimResult(
            finished=finished,
            timeline=timeline,
            duration=now,
            manager=self.manager,
            straggler_mitigations=self.straggler_mitigations,
        )
