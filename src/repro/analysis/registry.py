"""Rule registry + shared project model for the libra-check lint pass.

A rule is a function ``check(module, ctx) -> list[Violation]`` registered
under a stable rule id. The driver (:mod:`repro.analysis.lint`) parses the
whole tree first into a :class:`ProjectContext` so rules can reason across
modules (e.g. host-sync reachability from the engine step loop spans
``engine.py`` and ``prefill.py``), then runs every rule over every module.

Adding a rule::

    from .registry import Violation, register

    @register(
        "my-rule",
        summary="one-line description shown by --list-rules",
        rationale="why this pattern is a hazard in this codebase",
    )
    def check_my_rule(module, ctx):
        return [Violation("my-rule", module.path, node.lineno,
                          node.col_offset, "message")
                for node in ...]

Rules must be pure (no filesystem access beyond ``module``/``ctx``) and
stdlib-only — the CI lint job runs without the accelerator toolchain.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Callable, Iterable, Optional


@dataclasses.dataclass(frozen=True, order=True)
class Violation:
    """One lint finding, addressable to a source position."""

    path: str
    line: int
    col: int
    rule_id: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule_id} {self.message}"


@dataclasses.dataclass(frozen=True)
class ModuleInfo:
    """A parsed source module: AST + raw lines for suppression matching."""

    path: str
    tree: ast.Module
    lines: tuple[str, ...]

    @property
    def package_dir(self) -> str:
        return str(Path(self.path).parent)


@dataclasses.dataclass
class ProjectContext:
    """Every parsed module of the lint run, for cross-module rules."""

    modules: list[ModuleInfo]

    def modules_in_dir(self, package_dir: str) -> list[ModuleInfo]:
        return [m for m in self.modules if m.package_dir == package_dir]


CheckFn = Callable[[ModuleInfo, ProjectContext], Iterable[Violation]]


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    summary: str
    rationale: str
    check: CheckFn


_RULES: dict[str, Rule] = {}


def register(rule_id: str, *, summary: str, rationale: str):
    """Decorator: add a check function to the global rule table."""

    def deco(fn: CheckFn) -> CheckFn:
        if rule_id in _RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        _RULES[rule_id] = Rule(rule_id, summary, rationale, fn)
        return fn

    return deco


def all_rules() -> list[Rule]:
    """Registered rules, stable order. Importing this module alone returns
    an empty table — the driver imports the rule modules for their
    registration side effects."""
    return [_RULES[k] for k in sorted(_RULES)]


def get_rule(rule_id: str) -> Optional[Rule]:
    return _RULES.get(rule_id)


# --------------------------------------------------------------- AST helpers
def dotted_name(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` attribute/name chains; None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_calls(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def const_str_elems(node: ast.AST) -> list[str]:
    """String constants inside a tuple/list/single-constant AST node."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [
            e.value
            for e in node.elts
            if isinstance(e, ast.Constant) and isinstance(e.value, str)
        ]
    return []
