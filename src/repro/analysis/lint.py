"""libra-check lint driver + CLI.

Usage::

    python -m repro.analysis.lint src/            # lint a tree
    python -m repro.analysis.lint --list-rules    # show registered rules
    python -m repro.analysis.lint src/ --report lint-report.txt

Exit status is 0 iff no unsuppressed violation was found — CI runs this as
a blocking job. A violation is suppressed by a ``# libra: ignore[<rule-id>]``
comment (with a justification after it) on the flagged line or the line
directly above; ``ignore[*]`` suppresses every rule on that line. Unknown
rule ids in suppressions are themselves reported, so stale suppressions
cannot rot silently.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path
from typing import Iterable, Optional

from . import rules_hygiene, rules_jax  # noqa: F401 - rule registration
from .registry import ModuleInfo, ProjectContext, Violation, all_rules

_SUPPRESS_RE = re.compile(r"#\s*libra:\s*ignore\[([a-z*][a-z0-9*,\- ]*)\]")


def _suppressions_for(module: ModuleInfo, line: int) -> set[str]:
    """Rule ids suppressed at ``line`` (1-indexed): same line or line above."""
    out: set[str] = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(module.lines):
            m = _SUPPRESS_RE.search(module.lines[ln - 1])
            if m:
                out.update(p.strip() for p in m.group(1).split(","))
    return out


def parse_tree(paths: Iterable[str]) -> tuple[ProjectContext, list[Violation]]:
    """Parse every .py under ``paths``; syntax errors become violations."""
    modules: list[ModuleInfo] = []
    errors: list[Violation] = []
    files: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        else:
            files.append(path)
    for f in files:
        src = f.read_text()
        try:
            tree = ast.parse(src, filename=str(f))
        except SyntaxError as e:
            errors.append(Violation(
                str(f), e.lineno or 0, e.offset or 0, "syntax-error", str(e.msg)
            ))
            continue
        modules.append(ModuleInfo(str(f), tree, tuple(src.splitlines())))
    return ProjectContext(modules), errors


def run_lint(paths: Iterable[str]) -> list[Violation]:
    """Run every registered rule; returns unsuppressed violations, sorted."""
    ctx, violations = parse_tree(paths)
    known = {r.rule_id for r in all_rules()}
    for module in ctx.modules:
        raw: list[Violation] = []
        for rule in all_rules():
            raw.extend(rule.check(module, ctx))
        for v in raw:
            sup = _suppressions_for(module, v.line)
            if v.rule_id in sup or "*" in sup:
                continue
            violations.append(v)
        # stale/unknown suppression ids are findings too
        for i, text in enumerate(module.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            for rid in (p.strip() for p in m.group(1).split(",")):
                if rid != "*" and rid not in known:
                    violations.append(Violation(
                        module.path, i, text.index("#"), "unknown-suppression",
                        f"suppression names unknown rule {rid!r}",
                    ))
    return sorted(violations)


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="libra-check: JAX-aware static lint for the repro tree",
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--report", metavar="FILE",
                    help="also write the findings to FILE")
    ap.add_argument("--list-rules", action="store_true",
                    help="print registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.rule_id:22s} {rule.summary}")
            print(f"{'':22s}   {rule.rationale}")
        return 0

    violations = run_lint(args.paths or ["src"])
    lines = [v.render() for v in violations]
    body = "\n".join(lines)
    if args.report:
        Path(args.report).write_text(
            body + ("\n" if body else "")
            or "libra-check: no violations\n"
        )
    if violations:
        print(body)
        print(f"\nlibra-check: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("libra-check: no violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
