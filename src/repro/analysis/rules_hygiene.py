"""Hygiene rules: asserts that vanish under -O, dict-order-dependent ties,
wall-clock/print usage on serving hot paths.

These are generic-Python hazards, but both have bitten (or nearly bitten)
this codebase specifically: the pool's structural checks were ``assert``
statements — gone under ``python -O``, exactly when a production serving
deployment would run — and every eviction/prefetch decision is a
``min``/``max`` over scorer floats whose ties (e.g. freshly-registered
LoRAs with identical scores) resolve by dict insertion order, making victim
choice depend on registration order rather than anything intentional.
"""

from __future__ import annotations

import ast
from pathlib import PurePath

from .registry import ModuleInfo, ProjectContext, Violation, dotted_name, register


def _enclosing_functions(tree: ast.Module) -> dict[int, str]:
    """Map id(node) -> name of the innermost enclosing function."""
    owner: dict[int, str] = {}

    def visit(node: ast.AST, fname: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                visit(child, child.name)
            else:
                owner[id(child)] = fname
                visit(child, fname)

    visit(tree, "<module>")
    return owner


def _is_check_context(fname: str) -> bool:
    """Functions whose whole job is validation may assert: they run only in
    debug/test sweeps, so -O stripping them is acceptable by design."""
    low = fname.lower()
    return (
        low.startswith("check") or low.startswith("_check")
        or "invariant" in low or low.startswith("test")
    )


@register(
    "bare-assert",
    summary="bare assert on a runtime path (stripped under python -O)",
    rationale=(
        "assert compiles to nothing under -O, so a corruption guard on a "
        "mutation path silently disappears in optimized deployments; raise "
        "PoolInvariantError/ValueError instead (check_*/test_* functions "
        "are exempt — they exist only for debug sweeps)"
    ),
)
def check_bare_assert(module: ModuleInfo, ctx: ProjectContext):
    owner = _enclosing_functions(module.tree)
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Assert):
            continue
        fname = owner.get(id(node), "<module>")
        if _is_check_context(fname):
            continue
        out.append(Violation(
            module.path, node.lineno, node.col_offset, "bare-assert",
            f"assert in {fname!r} vanishes under python -O; raise a typed "
            f"error instead",
        ))
    return out


@register(
    "dict-order-tiebreak",
    summary="min/max selection whose ties resolve by dict/insertion order",
    rationale=(
        "min()/max() with a scalar key returns the *first* minimal element, "
        "so equal scores (cold nodes, fresh LoRAs) make eviction/prefetch "
        "choices depend on insertion order — nondeterministic across runs "
        "and impossible to reproduce; break ties explicitly with a tuple "
        "key (score, node_id)"
    ),
)
def check_dict_order_tiebreak(module: ModuleInfo, ctx: ProjectContext):
    out = []
    for call in ast.walk(module.tree):
        if not isinstance(call, ast.Call):
            continue
        if not isinstance(call.func, ast.Name) or call.func.id not in ("min", "max"):
            continue
        key = next((k for k in call.keywords if k.arg == "key"), None)
        if key is None or not isinstance(key.value, ast.Lambda):
            continue
        body = key.value.body
        if isinstance(body, ast.Tuple):
            continue  # explicit tuple key = deliberate tiebreak
        out.append(Violation(
            module.path, call.lineno, call.col_offset, "dict-order-tiebreak",
            f"{call.func.id}() with a scalar key resolves ties by iteration "
            f"order; use a tuple key with an explicit tiebreak",
        ))
    return out


def _in_hot_package(path: str) -> bool:
    """True for modules under the serving hot path (src/repro/{core,serving})."""
    return bool({"core", "serving"} & set(PurePath(path).parts))


@register(
    "raw-clock",
    summary="time.time() / print() on a core/serving hot path",
    rationale=(
        "the engine and cache pool run inside the serving step loop: "
        "time.time() is wall-clock (jumps under NTP slew, breaks the "
        "monotonic engine-clock contract every TTFT/queue metric and the "
        "libra-trace timeline assume — use time.monotonic()/perf_counter()), "
        "and print() is synchronous unbuffered I/O per call on the hot path "
        "— emit through the Tracer (repro.obs) or a logger instead"
    ),
)
def check_raw_clock(module: ModuleInfo, ctx: ProjectContext):
    if not _in_hot_package(module.path):
        return []
    out = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name == "time.time":
            out.append(Violation(
                module.path, node.lineno, node.col_offset, "raw-clock",
                "wall-clock time.time() on a hot path; use the monotonic "
                "engine clock (time.monotonic()/perf_counter())",
            ))
        elif name == "print":
            out.append(Violation(
                module.path, node.lineno, node.col_offset, "raw-clock",
                "print() on a hot path; emit through the Tracer "
                "(repro.obs) or a logger",
            ))
    return out
