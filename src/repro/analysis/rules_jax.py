"""JAX-aware lint rules: tracer leaks, host syncs, recompile storms.

All three rules are first-order static approximations (documented per rule);
they are tuned to this codebase's idioms — ``@partial(jax.jit, ...)``
decorated kernels, ``self._fn = jax.jit(self._method)`` engine entry points
— and err toward silence on constructs they cannot resolve. A false
negative costs a missed review comment; a false positive costs a suppression
with a justification, so precision wins.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Optional

from .registry import (
    ModuleInfo,
    ProjectContext,
    Violation,
    const_str_elems,
    dotted_name,
    register,
)

_JIT_NAMES = {"jax.jit", "jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
# attribute reads on a traced array that yield static Python values — safe
# to branch on inside jit
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_JNP_ROOTS = ("jnp", "jax")


@dataclasses.dataclass
class JittedFn:
    fn: ast.FunctionDef
    static_names: set[str]
    jit_site_line: int  # where the jax.jit wrapping happens


def _jit_call_statics(call: ast.Call, params: list[str]) -> set[str]:
    """static_argnames/static_argnums of a ``jax.jit(...)``-style call,
    resolved to parameter names."""
    out: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            out.update(const_str_elems(kw.value))
        elif kw.arg == "static_argnums":
            nums = []
            if isinstance(kw.value, ast.Constant) and isinstance(kw.value.value, int):
                nums = [kw.value.value]
            elif isinstance(kw.value, (ast.Tuple, ast.List)):
                nums = [
                    e.value
                    for e in kw.value.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, int)
                ]
            for i in nums:
                if 0 <= i < len(params):
                    out.add(params[i])
    return out


def _fn_params(fn: ast.FunctionDef) -> list[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    return [n for n in names if n != "self"]


def _decorator_jit_statics(fn: ast.FunctionDef) -> Optional[set[str]]:
    """If ``fn`` is jit-decorated, return its static param names (approx)."""
    params = _fn_params(fn)
    for deco in fn.decorator_list:
        name = dotted_name(deco)
        if name in _JIT_NAMES:
            return set()
        if isinstance(deco, ast.Call):
            cname = dotted_name(deco.func)
            if cname in _JIT_NAMES:
                return _jit_call_statics(deco, params)
            if cname in _PARTIAL_NAMES and deco.args:
                if dotted_name(deco.args[0]) in _JIT_NAMES:
                    return _jit_call_statics(deco, params)
    return None


def jitted_functions(module: ModuleInfo) -> list[JittedFn]:
    """Every function in ``module`` that runs under jax.jit, with its static
    params. First-order: decorated defs, plus ``jax.jit(name)`` /
    ``jax.jit(self.method)`` wrapping calls resolved by final name component
    within the module. Lambdas and higher-order factories are not resolved."""
    fns = {
        n.name: n
        for n in ast.walk(module.tree)
        if isinstance(n, ast.FunctionDef)
    }
    out: dict[int, JittedFn] = {}
    for fn in fns.values():
        statics = _decorator_jit_statics(fn)
        if statics is not None:
            out[id(fn)] = JittedFn(fn, statics, fn.lineno)
    for call in ast.walk(module.tree):
        if not isinstance(call, ast.Call) or dotted_name(call.func) not in _JIT_NAMES:
            continue
        if not call.args:
            continue
        target = call.args[0]
        tname = None
        if isinstance(target, ast.Name):
            tname = target.id
        elif isinstance(target, ast.Attribute):
            tname = target.attr  # self._method / cls.method
        fn = fns.get(tname)
        if fn is None:
            continue
        statics = _jit_call_statics(call, _fn_params(fn))
        prev = out.get(id(fn))
        if prev is not None:
            prev.static_names |= statics
        else:
            out[id(fn)] = JittedFn(fn, statics, call.lineno)
    return list(out.values())


def _blessed_names(test: ast.AST) -> set[int]:
    """ids of Name nodes inside ``test`` used only in trace-safe positions:
    under ``.shape/.ndim/.dtype/.size``, inside ``len()``/``isinstance()``,
    or compared ``is (not) None``."""
    blessed: set[int] = set()

    def bless(sub: ast.AST) -> None:
        for n in ast.walk(sub):
            if isinstance(n, ast.Name):
                blessed.add(id(n))

    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            bless(n.value)
        elif isinstance(n, ast.Call):
            fname = dotted_name(n.func)
            if fname in ("len", "isinstance"):
                for a in n.args:
                    bless(a)
        elif isinstance(n, ast.Compare):
            ops_none = all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops
            ) and all(
                isinstance(c, ast.Constant) and c.value is None
                for c in n.comparators
            )
            if ops_none and n.comparators:
                bless(n.left)
    return blessed


def _traced_uses(expr: ast.AST, traced: set[str]) -> list[ast.Name]:
    blessed = _blessed_names(expr)
    return [
        n
        for n in ast.walk(expr)
        if isinstance(n, ast.Name)
        and isinstance(n.ctx, ast.Load)
        and n.id in traced
        and id(n) not in blessed
    ]


@register(
    "traced-branch",
    summary="Python control flow on a traced value inside a jitted function",
    rationale=(
        "if/while/for on a tracer raises ConcretizationTypeError at runtime "
        "or, worse, silently bakes one branch into the compiled program; "
        "use lax.cond/select/where or mark the argument static"
    ),
)
def check_traced_branch(module: ModuleInfo, ctx: ProjectContext):
    out = []
    for jf in jitted_functions(module):
        traced = set(_fn_params(jf.fn)) - jf.static_names
        # local names rebound inside the function shadow params
        for node in ast.walk(jf.fn):
            tests: list[tuple[ast.AST, str]] = []
            if isinstance(node, (ast.If, ast.While)):
                tests.append((node.test, type(node).__name__.lower()))
            elif isinstance(node, ast.IfExp):
                tests.append((node.test, "conditional expression"))
            elif isinstance(node, ast.For):
                tests.append((node.iter, "for-loop iterable"))
            for expr, what in tests:
                for use in _traced_uses(expr, traced):
                    out.append(Violation(
                        module.path, use.lineno, use.col_offset,
                        "traced-branch",
                        f"{what} depends on traced argument {use.id!r} of "
                        f"jitted function {jf.fn.name!r}",
                    ))
    return out


_SHAPE_FNS = {
    "jnp.zeros", "jnp.ones", "jnp.empty", "jnp.full", "jnp.arange",
    "jnp.broadcast_to",
}


@register(
    "nonstatic-jit-arg",
    summary="traced argument used where a static Python value is required",
    rationale=(
        "range()/shape arguments inside jit must be compile-time constants; "
        "feeding a traced value either errors or forces a recompile per "
        "distinct value, turning the jit cache into a compile storm"
    ),
)
def check_nonstatic_jit_arg(module: ModuleInfo, ctx: ProjectContext):
    out = []
    for jf in jitted_functions(module):
        traced = set(_fn_params(jf.fn)) - jf.static_names
        for call in ast.walk(jf.fn):
            if not isinstance(call, ast.Call):
                continue
            fname = dotted_name(call.func)
            shape_args: list[ast.AST] = []
            if fname == "range":
                shape_args = list(call.args)
            elif fname in _SHAPE_FNS and call.args:
                shape_args = [call.args[0]]
                if fname == "jnp.broadcast_to" and len(call.args) > 1:
                    shape_args = [call.args[1]]
            elif (
                isinstance(call.func, ast.Attribute)
                and call.func.attr == "reshape"
            ):
                shape_args = list(call.args)
            for arg in shape_args:
                for use in _traced_uses(arg, traced):
                    out.append(Violation(
                        module.path, use.lineno, use.col_offset,
                        "nonstatic-jit-arg",
                        f"traced argument {use.id!r} of jitted function "
                        f"{jf.fn.name!r} flows into a static "
                        f"(shape/range) position of {fname or 'reshape'} — "
                        f"mark it static or derive it from .shape",
                    ))
    return out


# --------------------------------------------------------------- host-sync
def _class_methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}


def _hot_functions(module: ModuleInfo, ctx: ProjectContext) -> list[ast.FunctionDef]:
    """The engine hot path, approximated: methods reachable from the
    ``step``/``run`` methods of ``*Engine`` classes via ``self.x()`` calls
    and same-module bare calls — plus, for modules living in a package that
    defines an Engine, every top-level class's ``__call__`` (engines invoke
    collaborators like the batch-prefill runner through ``__call__``)."""
    hot: dict[int, ast.FunctionDef] = {}
    module_fns = {
        n.name: n for n in module.tree.body if isinstance(n, ast.FunctionDef)
    }

    def expand(fn: ast.FunctionDef, methods: dict[str, ast.FunctionDef]) -> None:
        if id(fn) in hot:
            return
        hot[id(fn)] = fn
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            if (
                isinstance(f, ast.Attribute)
                and isinstance(f.value, ast.Name)
                and f.value.id == "self"
                and f.attr in methods
            ):
                expand(methods[f.attr], methods)
            elif isinstance(f, ast.Name) and f.id in module_fns:
                expand(module_fns[f.id], methods)

    pkg_has_engine = False
    for m in ctx.modules_in_dir(module.package_dir):
        for n in m.tree.body:
            if isinstance(n, ast.ClassDef) and "Engine" in n.name:
                pkg_has_engine = True
    for n in module.tree.body:
        if not isinstance(n, ast.ClassDef):
            continue
        methods = _class_methods(n)
        if "Engine" in n.name:
            for entry in ("step", "run"):
                if entry in methods:
                    expand(methods[entry], methods)
        elif pkg_has_engine and "__call__" in methods:
            expand(methods["__call__"], methods)
    return list(hot.values())


def _is_device_expr(node: ast.AST) -> bool:
    """Whether the expression statically references jnp./jax. values."""
    for n in ast.walk(node):
        name = dotted_name(n)
        if name and name.split(".", 1)[0] in _JNP_ROOTS:
            return True
    return False


@register(
    "host-sync",
    summary="device→host synchronization reachable from the engine step loop",
    rationale=(
        ".item()/int()/np.asarray() on a device value blocks the dispatch "
        "queue and serializes the step loop with the accelerator — the TTFT "
        "wins of batched prefill die here; keep values on device or batch "
        "the transfer once per step"
    ),
)
def check_host_sync(module: ModuleInfo, ctx: ProjectContext):
    out = []
    for fn in _hot_functions(module, ctx):
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            if isinstance(f, ast.Attribute) and f.attr in (
                "item", "block_until_ready", "tolist",
            ):
                out.append(Violation(
                    module.path, call.lineno, call.col_offset, "host-sync",
                    f".{f.attr}() in hot function {fn.name!r} forces a "
                    f"device→host sync",
                ))
                continue
            fname = dotted_name(f)
            if fname == "jax.device_get":
                out.append(Violation(
                    module.path, call.lineno, call.col_offset, "host-sync",
                    f"jax.device_get in hot function {fn.name!r} forces a "
                    f"device→host sync",
                ))
            elif fname in ("int", "float", "bool") and any(
                _is_device_expr(a) for a in call.args
            ):
                out.append(Violation(
                    module.path, call.lineno, call.col_offset, "host-sync",
                    f"{fname}() over a device expression in hot function "
                    f"{fn.name!r} forces a device→host sync",
                ))
            elif fname and fname.split(".", 1)[0] in ("np", "numpy") and any(
                _is_device_expr(a) for a in call.args
            ):
                out.append(Violation(
                    module.path, call.lineno, call.col_offset, "host-sync",
                    f"{fname}() over a device expression in hot function "
                    f"{fn.name!r} implicitly copies device→host",
                ))
    return out
