"""libra-check static layer: a JAX-aware AST lint pass over the repo.

``python -m repro.analysis.lint src/`` runs every registered rule over the
tree and exits non-zero on violations (CI runs it as a blocking job). Rules
target the hazards that silently destroy a JAX serving engine's latency
wins or strip its safety net:

* ``traced-branch``     — Python control flow on traced values inside jit
* ``host-sync``         — device→host syncs reachable from the engine step loop
* ``nonstatic-jit-arg`` — jit signatures that recompile per Python value
* ``bare-assert``       — ``assert`` on mutation paths (vanishes under -O)
* ``dict-order-tiebreak`` — min/max scheduling decisions whose ties resolve
  by dict/insertion order
* ``raw-clock``         — wall-clock ``time.time()`` / ``print()`` calls in
  the ``core``/``serving`` hot packages (monotonic-clock contract, hot-path
  I/O; emit via :mod:`repro.obs` instead)

This package is stdlib-only (no jax import) so the lint job needs no
accelerator toolchain. See :mod:`repro.analysis.registry` for how to add a
rule and ``README.md`` for the suppression syntax
(``# libra: ignore[<rule-id>]``).
"""

from .registry import Rule, Violation, all_rules, register

__all__ = ["Rule", "Violation", "all_rules", "register"]
