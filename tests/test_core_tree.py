"""Unit tests for the usage-dependency tree (FASTLIBRA §4)."""

import pytest

from repro.core import DependencyTree, NodeKind, Residency


def make_tree(align=1):
    t = DependencyTree(align=align, decay_tau=0.0)
    t.add_lora("l1", size_bytes=100, num_blocks=1, tier=Residency.HBM)
    t.add_lora("l2", size_bytes=100, num_blocks=1, tier=Residency.HOST)
    return t


def test_lora_layer_two():
    t = make_tree()
    for n in t.lora_nodes():
        assert n.parent is t.root
        assert n.kind is NodeKind.LORA


def test_match_empty_tree():
    t = make_tree()
    m = t.match("l1", (1, 2, 3), now=1.0)
    assert m.lora_node is t.lora_node("l1")
    assert m.matched_tokens == 0
    assert m.kv_nodes == []
    assert m.last_node is t.lora_node("l1")


def test_match_unknown_lora():
    t = make_tree()
    m = t.match("nope", (1, 2), now=0.0)
    assert m.lora_node is None and m.matched_tokens == 0


def test_insert_and_match_chain():
    t = make_tree()
    l1 = t.lora_node("l1")
    a = t.insert_kv(l1, (1, 2, 3, 4), 40, 1, Residency.HBM, now=0.0)
    b = t.insert_kv(a, (5, 6), 20, 1, Residency.HBM, now=0.0)
    m = t.match("l1", (1, 2, 3, 4, 5, 6, 7), now=1.0)
    assert m.matched_tokens == 6
    assert m.kv_nodes == [a, b]
    assert m.last_node is b


def test_radix_split_on_divergence():
    t = make_tree()
    l1 = t.lora_node("l1")
    t.insert_kv(l1, (1, 2, 3, 4), 40, 4, Residency.HBM, now=0.0)
    n2 = t.insert_kv(l1, (1, 2, 9, 9), 40, 4, Residency.HBM, now=0.0)
    # the shared (1,2) prefix must have been factored out
    m = t.match("l1", (1, 2, 9, 9), now=1.0)
    assert m.matched_tokens == 4
    assert m.kv_nodes[-1] is n2
    assert m.kv_nodes[0].tokens == (1, 2)
    m2 = t.match("l1", (1, 2, 3, 4), now=1.0)
    assert m2.matched_tokens == 4
    assert m2.kv_nodes[0] is m.kv_nodes[0]


def test_split_preserves_size_bytes():
    t = make_tree()
    l1 = t.lora_node("l1")
    t.insert_kv(l1, (1, 2, 3, 4), 40, 4, Residency.HBM, now=0.0)
    t.insert_kv(l1, (1, 2, 9), 30, 3, Residency.HBM, now=0.0)
    total = sum(n.size_bytes for n in t.iter_nodes({NodeKind.KV}))
    # 40 split into 20+20, plus 10 for the (9,) suffix
    assert total == 50


def test_branches_are_independent_per_lora():
    t = make_tree()
    t.insert_kv(t.lora_node("l1"), (1, 2), 20, 1, Residency.HBM, now=0.0)
    m = t.match("l2", (1, 2), now=1.0)
    assert m.matched_tokens == 0


def test_align_quantizes_match():
    t = DependencyTree(align=4, decay_tau=0.0)
    t.add_lora("l1", 100, 1, tier=Residency.HBM)
    l1 = t.lora_node("l1")
    t.insert_kv(l1, (1, 2, 3, 4), 40, 1, Residency.HBM, now=0.0)
    # 6 usable tokens quantize down to 4
    m = t.match("l1", (1, 2, 3, 4, 5, 6), now=1.0)
    assert m.matched_tokens == 4


def test_hbm_leaves_and_host_roots():
    t = make_tree()
    l1 = t.lora_node("l1")
    a = t.insert_kv(l1, (1,), 10, 1, Residency.HBM, now=0.0)
    b = t.insert_kv(a, (2,), 10, 1, Residency.HOST, now=0.0)
    c = t.insert_kv(b, (3,), 10, 1, Residency.HOST, now=0.0)
    leaves = t.hbm_leaves()
    assert a in leaves  # a's only child is HOST-resident
    assert t.lora_node("l1") not in leaves  # has HBM child a
    roots = t.host_roots()
    assert b in roots and c not in roots  # c's parent is host
    assert t.lora_node("l2") in roots  # host LoRA under (virtual) root


def test_pinned_not_a_leaf_candidate():
    t = make_tree()
    a = t.insert_kv(t.lora_node("l1"), (1,), 10, 1, Residency.HBM, now=0.0)
    a.ref_count = 1
    assert a not in t.hbm_leaves()


def test_validity_invariant_detects_violation():
    t = make_tree()
    l2 = t.lora_node("l2")  # HOST
    kv = t.insert_kv(l2, (1,), 10, 1, Residency.HBM, now=0.0)
    with pytest.raises(AssertionError):
        t.check_validity_invariant()
    assert t.invalid_hbm_bytes() == 10
    kv.tier = Residency.HOST
    t.check_validity_invariant()
    assert t.invalid_hbm_bytes() == 0


def test_visit_prob_normalizes():
    t = DependencyTree(align=1, decay_tau=0.0)
    t.add_lora("a", 1, 1)
    t.add_lora("b", 1, 1)
    for _ in range(3):
        t.match("a", (), now=1.0)
    t.match("b", (), now=1.0)
    pa = t.visit_prob(t.lora_node("a"), now=1.0)
    pb = t.visit_prob(t.lora_node("b"), now=1.0)
    assert pa == pytest.approx(0.75)
    assert pb == pytest.approx(0.25)


def test_decay_reduces_old_visits():
    t = DependencyTree(align=1, decay_tau=10.0)
    t.add_lora("a", 1, 1)
    t.match("a", (), now=0.0)
    n = t.lora_node("a")
    assert n.decayed_visits(0.0, 10.0) == pytest.approx(1.0)
    assert n.decayed_visits(100.0, 10.0) < 1e-3


def test_remove_leaf():
    t = make_tree()
    a = t.insert_kv(t.lora_node("l1"), (1,), 10, 1, Residency.HBM, now=0.0)
    t.remove(a)
    assert t.match("l1", (1,), now=1.0).matched_tokens == 0
    with pytest.raises(ValueError):
        b = t.insert_kv(t.lora_node("l1"), (1, 2), 10, 1, Residency.HBM, now=0.0)
        t.insert_kv(b, (3,), 10, 1, Residency.HBM, now=0.0)
        t.remove(b)  # has a child
