"""Tests for the libra-check static lint pass (repro.analysis).

Each rule gets a minimal synthetic module that must fire and a
counterpart that must stay clean (blessed constructs, static jit args,
check-context asserts, tuple tiebreaks). Suppression handling —
``# libra: ignore[...]`` on the line or directly above, wildcard, and
stale-id reporting — is exercised separately. Finally the real ``src/``
tree must lint clean: that is the same gate CI enforces.
"""

import subprocess
import sys
from pathlib import Path

from repro.analysis import all_rules
from repro.analysis.lint import main, run_lint

REPO = Path(__file__).resolve().parent.parent


def lint_src(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(source)
    return run_lint([str(p)])


def rule_ids(violations):
    return [v.rule_id for v in violations]


# ----------------------------------------------------------------- rules
def test_traced_branch_fires_and_blessed_is_clean(tmp_path):
    vs = lint_src(tmp_path, """\
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
""")
    assert rule_ids(vs) == ["traced-branch"]
    assert vs[0].line == 5

    clean = lint_src(tmp_path, """\
import jax

@jax.jit
def f(x):
    if x.ndim == 2:          # shape metadata: static under trace
        return x
    if x is None:            # identity test: static
        return x
    for i in range(x.shape[0]):
        pass
    return -x
""", name="clean.py")
    assert clean == []


def test_traced_branch_via_jit_wrapping_call(tmp_path):
    vs = lint_src(tmp_path, """\
import jax

def step(n):
    while n > 0:
        n = n - 1
    return n

fast_step = jax.jit(step)
""")
    assert rule_ids(vs) == ["traced-branch"]


def test_nonstatic_jit_arg_and_static_argnames(tmp_path):
    vs = lint_src(tmp_path, """\
import jax
import jax.numpy as jnp

@jax.jit
def g(n):
    return jnp.zeros(n)
""")
    assert rule_ids(vs) == ["nonstatic-jit-arg"]

    clean = lint_src(tmp_path, """\
from functools import partial
import jax
import jax.numpy as jnp

@partial(jax.jit, static_argnames=("n",))
def h(x, n):
    return x.reshape(n, -1) + jnp.zeros(n)
""", name="clean.py")
    assert clean == []


def test_host_sync_in_engine_hot_path(tmp_path):
    vs = lint_src(tmp_path, """\
import jax.numpy as jnp

class ToyEngine:
    def step(self):
        v = jnp.ones(3)
        return int(jnp.sum(v))

    def report(self):
        # not reachable from step/run: cold path, conversions are fine
        return float(jnp.zeros(()))
""")
    assert rule_ids(vs) == ["host-sync"]
    assert vs[0].line == 6


def test_bare_assert_and_check_context_exemption(tmp_path):
    vs = lint_src(tmp_path, """\
def mutate(xs):
    assert xs, "empty"
    return xs.pop()

def check_invariants(xs):
    assert xs  # check helpers may assert

def test_mutate():
    assert mutate([1]) == 1
""")
    assert rule_ids(vs) == ["bare-assert"]
    assert vs[0].line == 2


def test_dict_order_tiebreak(tmp_path):
    vs = lint_src(tmp_path, """\
def pick(nodes):
    return min(nodes, key=lambda n: n.score)

def pick_stable(nodes):
    return min(nodes, key=lambda n: (n.score, n.node_id))
""")
    assert rule_ids(vs) == ["dict-order-tiebreak"]
    assert vs[0].line == 2


def test_raw_clock_fires_in_hot_packages(tmp_path):
    hot = tmp_path / "core"
    hot.mkdir()
    (hot / "mod.py").write_text("""\
import time

def step():
    t = time.time()
    print("step", t)
    return t
""")
    vs = run_lint([str(hot / "mod.py")])
    assert rule_ids(vs) == ["raw-clock", "raw-clock"]
    assert [v.line for v in vs] == [4, 5]

    (hot / "clean.py").write_text("""\
import time

def step():
    return time.monotonic() + time.perf_counter()
""")
    assert run_lint([str(hot / "clean.py")]) == []


def test_raw_clock_ignores_cold_packages_and_suppressions(tmp_path):
    cold = tmp_path / "launch"
    cold.mkdir()
    (cold / "mod.py").write_text("""\
import time

def main():
    print("report:", time.time())
""")
    assert run_lint([str(cold / "mod.py")]) == []

    hot = tmp_path / "serving"
    hot.mkdir()
    (hot / "mod.py").write_text("""\
import time

def step():
    return time.time()  # libra: ignore[raw-clock]
""")
    assert run_lint([str(hot / "mod.py")]) == []


def test_syntax_error_is_reported_not_raised(tmp_path):
    vs = lint_src(tmp_path, "def broken(:\n")
    assert rule_ids(vs) == ["syntax-error"]


# ----------------------------------------------------------- suppression
def test_suppression_on_line_and_line_above(tmp_path):
    clean = lint_src(tmp_path, """\
def mutate(xs):
    assert xs  # libra: ignore[bare-assert]
    # libra: ignore[bare-assert]
    assert len(xs) > 1
    return xs.pop()
""")
    assert clean == []


def test_wildcard_suppression(tmp_path):
    clean = lint_src(tmp_path, """\
def mutate(xs):
    assert xs  # libra: ignore[*]
    return xs.pop()
""")
    assert clean == []


def test_unknown_suppression_is_itself_flagged(tmp_path):
    vs = lint_src(tmp_path, """\
x = 1  # libra: ignore[no-such-rule]
""")
    assert rule_ids(vs) == ["unknown-suppression"]
    assert "no-such-rule" in vs[0].message


def test_suppression_does_not_leak_to_other_rules(tmp_path):
    vs = lint_src(tmp_path, """\
def mutate(xs):
    # libra: ignore[dict-order-tiebreak]
    assert xs
    return xs.pop()
""")
    assert rule_ids(vs) == ["bare-assert"]


# ------------------------------------------------------------------- CLI
def test_cli_exit_codes_and_report(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(xs):\n    assert xs\n")
    report = tmp_path / "report.txt"
    assert main([str(bad), "--report", str(report)]) == 1
    assert "bare-assert" in report.read_text()
    capsys.readouterr()

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert main([str(good)]) == 0
    assert "no violations" in capsys.readouterr().out


def test_list_rules_covers_registry(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in all_rules():
        assert rule.rule_id in out
    assert len(all_rules()) >= 6


# ------------------------------------------------------------- real tree
def test_src_tree_lints_clean():
    """The blocking CI gate: the shipped tree has zero violations."""
    vs = run_lint([str(REPO / "src")])
    assert vs == [], "\n".join(v.render() for v in vs)


def test_module_entry_point():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", str(REPO / "src")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "no violations" in proc.stdout
