"""Tests: checkpoint/restore (incl. crash safety), elastic planning,
gradient compression, request journal."""

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.distributed import (
    CheckpointManager,
    RequestJournal,
    compress_decompress,
    init_state,
    plan_mesh,
    wire_bytes,
)
from repro.models import build_model, make_train_state, make_train_step


def small_state():
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    model = build_model(cfg, dtype=jnp.float32)
    return model, make_train_state(model, jax.random.PRNGKey(0), n_lora_slots=2)


def test_checkpoint_roundtrip(tmp_path):
    model, state = small_state()
    mgr = CheckpointManager(tmp_path, keep=2)
    mgr.save(1, state)
    assert mgr.latest_step() == 1
    like = jax.eval_shape(lambda: state)
    restored = mgr.restore(1, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    model, state = small_state()
    mgr = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3):
        mgr.save_async(step, state)
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [2, 3]  # keep=2 garbage-collected step 1


def test_checkpoint_crash_safety(tmp_path):
    """A leftover .tmp dir must not corrupt or shadow the latest ckpt."""
    model, state = small_state()
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, state)
    # simulate a crashed save
    (tmp_path / "step_0000000006.tmp").mkdir()
    assert mgr.latest_step() == 5
    mgr.save(6, state)  # overwrite the stale tmp cleanly
    assert mgr.latest_step() == 6


def test_restore_after_training_continues(tmp_path):
    model, state = small_state()
    step_fn = jax.jit(make_train_step(model, lr=1e-3))
    batch = {
        "tokens": jnp.ones((2, 8), jnp.int32),
        "labels": jnp.ones((2, 8), jnp.int32),
        "adapter_ids": jnp.zeros((2,), jnp.int32),
    }
    state1, m1 = step_fn(state, batch)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, state1)
    restored = mgr.restore(1, jax.eval_shape(lambda: state1))
    state2a, m2a = step_fn(state1, batch)
    state2b, m2b = step_fn(restored, batch)
    assert float(m2a["loss"]) == pytest.approx(float(m2b["loss"]), rel=1e-6)


def test_elastic_plan_shapes():
    p = plan_mesh(512, preferred_model=16)
    assert (p.data, p.model, p.dropped_devices) == (32, 16, 0)
    # one host of 8 chips lost from 256: 248 = 2^3 * 31
    p = plan_mesh(248, preferred_model=16, model_divisor_of=32)
    assert p.size <= 248 and p.model in (1, 2, 4, 8, 16)
    assert 32 % p.model == 0
    assert p.size >= 240  # uses nearly everything
    # tiny clusters still work
    p = plan_mesh(3, preferred_model=16)
    assert p.size == 3 and p.model == 3 or p.size <= 3


def test_compression_error_feedback_converges():
    g = {"w": jnp.linspace(-1, 1, 64).reshape(8, 8), "b": jnp.ones((4,))}
    st = init_state(g)
    # summing many compressed rounds ≈ summing uncompressed (error feedback)
    total_c = jax.tree.map(jnp.zeros_like, g)
    for _ in range(50):
        c, st = compress_decompress(g, st)
        total_c = jax.tree.map(lambda a, b: a + b, total_c, c)
    total = jax.tree.map(lambda a: a * 50.0, g)
    for a, b in zip(jax.tree.leaves(total_c), jax.tree.leaves(total)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.02, atol=0.05)


def test_compression_wire_bytes():
    g = {"w": jnp.zeros((128, 256), jnp.float32)}
    full = wire_bytes(g, compressed=False)
    comp = wire_bytes(g, compressed=True)
    assert comp < full / 3.5  # ~4x reduction


def test_request_journal_replay(tmp_path):
    j = RequestJournal(tmp_path / "journal.jsonl")
    j.record_submit("r1", "lora-0", (1, 2, 3), 8)
    j.record_submit("r2", "lora-1", (4, 5), 4)
    j.record_finish("r1")
    pending = j.replay()
    assert len(pending) == 1 and pending[0]["rid"] == "r2"
    assert pending[0]["prompt"] == [4, 5]
