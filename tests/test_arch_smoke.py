"""Per-architecture smoke tests: reduced config, one forward + one decode +
one train step on CPU; asserts shapes and finiteness (no NaNs).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (
    build_model,
    make_decode_step,
    make_prefill_step,
    make_train_state,
    make_train_step,
)

B, S = 2, 16
N_LORA = 3


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "adapter_ids": jnp.array([0, 1], jnp.int32),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[2], (B, S // 2, cfg.d_model), jnp.float32)
    if cfg.frontend == "vision":
        batch["extra_embeds"] = jax.random.normal(ks[2], (B, S, cfg.d_model), jnp.float32) * 0.1
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_decode_parity_and_train(arch):
    cfg = configs.reduced(configs.get(arch))
    model = build_model(cfg, dtype=jnp.float32)
    key = jax.random.PRNGKey(0)
    state = make_train_state(model, key, n_lora_slots=N_LORA)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # ---- full forward -----------------------------------------------------
    if cfg.is_encdec:
        logits, aux = model.forward(state.params, batch["frames"], batch["tokens"],
                                    lora=state.lora, adapter_ids=batch["adapter_ids"])
    else:
        logits, aux = model.forward(state.params, batch["tokens"], lora=state.lora,
                                    adapter_ids=batch["adapter_ids"],
                                    extra_embeds=batch.get("extra_embeds"),
                                    mrope_positions=batch.get("mrope_positions"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in forward logits"

    # ---- prefill + decode matches forward ---------------------------------
    pf_tokens = batch["tokens"][:, : S - 1]
    if cfg.is_encdec:
        logits_pf, cache = model.prefill(state.params, batch["frames"], pf_tokens,
                                         max_len=S, lora=state.lora,
                                         adapter_ids=batch["adapter_ids"])
    else:
        logits_pf, cache = model.prefill(
            state.params, pf_tokens, max_len=S, lora=state.lora,
            adapter_ids=batch["adapter_ids"],
            extra_embeds=(batch["extra_embeds"][:, : S - 1]
                          if "extra_embeds" in batch else None),
            mrope_positions=(batch["mrope_positions"][:, :, : S - 1]
                             if "mrope_positions" in batch else None))
    assert logits_pf.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits_pf)))
    # prefill last-token logits == forward logits at S-2 (same prefix)
    np.testing.assert_allclose(
        np.asarray(logits_pf[:, 0]), np.asarray(logits[:, S - 2]),
        rtol=2e-4, atol=2e-4,
    )

    # decode one step: feeding token S-1 must reproduce forward logits at S-1
    if cfg.is_encdec or cfg.frontend != "vision":
        dec_tokens = batch["tokens"][:, S - 1 :]
        logits_dec, cache = model.decode(state.params, cache, dec_tokens,
                                         lora=state.lora,
                                         adapter_ids=batch["adapter_ids"])
        assert logits_dec.shape == (B, 1, cfg.vocab_size)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, 0]), np.asarray(logits[:, S - 1]),
            rtol=2e-4, atol=2e-4,
        )
        assert int(cache["len"][0]) == S

    # ---- one train step ----------------------------------------------------
    train_step = make_train_step(model, lr=1e-3)
    state2, metrics = jax.jit(train_step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually changed
    changed = jax.tree.leaves(
        jax.tree.map(lambda a, b: jnp.any(a != b), state.params, state2.params)
    )
    assert any(bool(c) for c in changed)


@pytest.mark.parametrize("arch", ["gemma-2b", "rwkv6-1.6b", "recurrentgemma-2b"])
def test_multi_step_decode(arch):
    """Greedy decode several tokens; cache length advances, logits finite."""
    cfg = configs.reduced(configs.get(arch))
    model = build_model(cfg, dtype=jnp.float32)
    state = make_train_state(model, jax.random.PRNGKey(0), n_lora_slots=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab_size)
    logits, cache = model.prefill(state.params, tokens, max_len=32,
                                  lora=state.lora,
                                  adapter_ids=jnp.zeros((B,), jnp.int32))
    decode = make_decode_step(model)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    for i in range(4):
        tok, cache = decode(state.params, state.lora, cache,
                            {"tokens": tok[:, None],
                             "adapter_ids": jnp.zeros((B,), jnp.int32)})
        assert tok.shape == (B,)
    assert int(cache["len"][0]) == 8 + 4


def test_lora_changes_output():
    cfg = configs.reduced(configs.get("gemma-2b"))
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    lora = model.init_lora(jax.random.PRNGKey(1), 2)
    # make adapter 1 nonzero on B so it changes outputs
    lora = jax.tree.map(lambda x: x, lora)
    a, b = lora["q"]
    lora["q"] = (a, b.at[:, 1].set(0.02))
    tokens = jnp.ones((2, 4), jnp.int32)
    ids0 = jnp.array([0, 0], jnp.int32)
    ids1 = jnp.array([1, 1], jnp.int32)
    l0, _ = model.forward(params, tokens, lora=lora, adapter_ids=ids0)
    l1, _ = model.forward(params, tokens, lora=lora, adapter_ids=ids1)
    assert not bool(jnp.allclose(l0, l1)), "adapter slot must affect output"
    # slot 0 has zero B => identical to no-lora
    lbase, _ = model.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(lbase), rtol=1e-5, atol=1e-5)
