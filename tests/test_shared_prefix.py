"""Cross-adapter prefix sharing: shared trunk + adapter forks.

Four pinned layers:
  P1  control plane: a declared shared span commits to a ``lora_id=None``
      trunk under the root; a DIFFERENT adapter's lookup hits it
      (block-quantized), forks diverge below it, and the baseline
      (``share_prefix_kv=False``) keeps everything per adapter
  P2  eviction economics: the swapper only ever offers leaves, so forks
      demote before the trunk they depend on; the cost model prices a
      multi-fork trunk node above a single-fork one; validity holds across
      trunk host-roundtrips
  P3  end-to-end differential: with a common system prompt across N
      adapters, shared-trunk serving is token-identical to the per-adapter
      baseline for GQA AND MLA layouts under mixed/alternate/eager modes —
      with a strictly higher HBM KV hit rate
  P4  the cold-adapter start: a row inside its declared shared span
      dispatches with adapter id -1 and needs no loaded adapter slot
"""

import itertools

import jax
import pytest

from repro import configs
from repro.core import NodeKind, Residency, make_fastlibra
from repro.serving import EngineConfig, Phase, Request, ServingEngine

KVB = 64
BS = 4
BLOCK_BYTES = KVB * BS


def _mgr(share=True, hbm_blocks=48, **kw):
    mgr, sw = make_fastlibra(
        hbm_bytes=hbm_blocks * BLOCK_BYTES,
        host_bytes=128 * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        sanitize=True,
        share_prefix_kv=share,
        **kw,
    )
    for lid in "abcd":
        mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
    return mgr, sw


def _serve(mgr, lid, toks, shared, qid, now):
    lk = mgr.lookup(lid, toks, now, shared_prefix_len=shared)
    adm = mgr.admit(lk, now)
    assert not adm.queued
    assert mgr.allocate_running(qid, len(toks) + 4, now) is not None
    mgr.commit(qid, lk, toks + tuple(range(900, 904)), now)
    mgr.unpin(adm.pinned)
    return lk


SYS = tuple(range(-50, -38))  # 12 shared system-prompt tokens


# ------------------------------------------------------------ P1: control
def test_trunk_commit_and_cross_adapter_hit():
    mgr, _ = _mgr()
    tail_a = SYS + tuple(range(100, 110))
    _serve(mgr, "a", tail_a, shared=len(SYS), qid="q0", now=1.0)
    trunk = mgr.tree.shared_nodes()
    assert trunk and all(n.lora_id is None for n in trunk)
    # trunk holds exactly the block-quantized shared span
    q = (len(SYS) // BS) * BS
    assert sum(n.num_tokens for n in trunk) == q
    # a DIFFERENT adapter with the same system prompt hits the trunk
    tail_b = SYS + tuple(range(200, 212))
    lk = mgr.lookup("b", tail_b, 2.0, shared_prefix_len=len(SYS))
    assert lk.match.matched_tokens == q
    assert lk.shared_hit_tokens == q
    assert mgr.stats.shared_hit_rate() > 0
    adm = mgr.admit(lk, 2.0)
    mgr.unpin(adm.pinned)


def test_forks_diverge_below_trunk_and_bytes_split():
    mgr, _ = _mgr()
    for i, lid in enumerate("ab"):
        _serve(mgr, lid, SYS + tuple(range(100 * (i + 1), 100 * (i + 1) + 10)),
               shared=len(SYS), qid=f"q{i}", now=1.0 + i)
    deepest = max(mgr.tree.shared_nodes(), key=lambda n: n.path_num_tokens())
    forks = [c for c in deepest.children.values() if c.lora_id is not None]
    assert sorted(c.lora_id for c in forks) == ["a", "b"]
    assert mgr.tree.dependent_fork_loras(deepest) == {"a", "b"}
    bd = mgr.hbm_breakdown()
    q = (len(SYS) // BS) * BS
    assert bd["shared_kv_bytes"] == q * KVB
    assert bd["history_kv_bytes"] > 0  # fork spans accounted separately
    mgr.check_invariants()


def test_disabled_sharing_keeps_per_adapter_caching():
    mgr, _ = _mgr(share=False)
    for i, lid in enumerate("ab"):
        _serve(mgr, lid, SYS + tuple(range(100 * (i + 1), 100 * (i + 1) + 10)),
               shared=len(SYS), qid=f"q{i}", now=1.0 + i)
    assert mgr.tree.shared_nodes() == []
    assert mgr.hbm_breakdown()["shared_kv_bytes"] == 0
    # adapter b's lookup must NOT see adapter a's system-prompt KV
    lk = mgr.lookup("c", SYS + (7, 8, 9, 10), 3.0, shared_prefix_len=len(SYS))
    assert lk.match.matched_tokens == 0
    adm = mgr.admit(lk, 3.0)
    mgr.unpin(adm.pinned)


def test_identical_adapter_repeat_still_matches_through_trunk():
    mgr, _ = _mgr()
    toks = SYS + tuple(range(300, 312))
    _serve(mgr, "a", toks, shared=len(SYS), qid="q0", now=1.0)
    lk = mgr.lookup("a", toks, 2.0, shared_prefix_len=len(SYS))
    # full prefix (trunk + own fork) matches, block-quantized
    assert lk.match.matched_tokens == (len(toks) // BS) * BS
    adm = mgr.admit(lk, 2.0)
    mgr.unpin(adm.pinned)


# --------------------------------------------------------- P2: eviction
def test_fork_demotes_before_trunk_and_cost_scales_with_forks():
    mgr, _ = _mgr()
    for i, lid in enumerate("abc"):
        _serve(mgr, lid, SYS + tuple(range(100 * (i + 1), 100 * (i + 1) + 8)),
               shared=len(SYS), qid=f"q{i}", now=1.0 + i)
    trunk = max(mgr.tree.shared_nodes(), key=lambda n: n.path_num_tokens())
    # leaf-only eviction: a trunk node with HBM forks is never a candidate
    assert trunk not in mgr.evict_candidates()
    # multi-fork trunk prices at least as high as any single fork's span
    three = mgr.scorer.retain_eval(trunk, 4.0)
    forks = [c for c in trunk.children.values() if c.lora_id is not None]
    mgr._swap_out_node(forks[0], 4.0)
    mgr._swap_out_node(forks[1], 4.0)
    mgr.drain_ops()
    one = mgr.scorer.retain_eval(trunk, 4.0)
    assert three >= one  # n_dep_forks shrank from 3 to 1
    mgr.check_invariants()


def test_trunk_host_roundtrip_preserves_validity_and_rehits():
    mgr, _ = _mgr()
    _serve(mgr, "a", SYS + tuple(range(100, 108)), shared=len(SYS),
           qid="q0", now=1.0)
    # demote the whole branch leaf-first (what the swapper sweep does)
    for _ in range(16):
        cands = mgr.evict_candidates()
        kv = [n for n in cands if n.kind is NodeKind.KV]
        if not kv:
            break
        mgr._swap_out_node(kv[0], 2.0)
    mgr.drain_ops()
    assert all(n.tier is not Residency.HBM for n in mgr.tree.shared_nodes())
    mgr.check_invariants()
    # a new adapter's shared lookup finds the host trunk; admit swaps it in
    lk = mgr.lookup("b", SYS + (5, 6, 7, 8), 3.0, shared_prefix_len=len(SYS))
    q = (len(SYS) // BS) * BS
    assert lk.match.matched_tokens == q
    assert lk.shared_hit_tokens == 0  # host hit, not an HBM hit
    adm = mgr.admit(lk, 3.0)
    assert not adm.queued
    assert all(n.tier is Residency.HBM for n in lk.match.kv_nodes)
    mgr.drain_ops()
    mgr.unpin(adm.pinned)
    mgr.check_invariants()


# ----------------------------------------------------- P3: differential
ARCHS = ["qwen3-0.6b", "deepseek-v2-lite-16b"]  # GQA, MLA
MODES = (("eager", "alternate"), ("bucketed", "mixed"),
         ("bucketed", "alternate"))

_ids = itertools.count()

N_ADAPTERS = 4
ESYS = tuple(range(500, 510))  # 10-token common system prompt


def _engine(arch, mode, schedule, share):
    cfg = configs.reduced(configs.get(arch))
    ecfg = EngineConfig(
        hbm_bytes=8 << 20, host_bytes=32 << 20, block_size=4,
        max_batch_slots=4, max_seq_len=96, prefill_mode=mode,
        prefill_chunk=8, prefill_min_bucket=4,
        schedule_mode=schedule, step_token_budget=24,
        share_prefix_kv=share,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(N_ADAPTERS):
        eng.register_adapter(f"lora-{i}")
    return eng


def _workload():
    """One request per adapter, all opening with the SAME system prompt."""
    return [
        Request(f"sp{next(_ids)}", f"lora-{i}",
                ESYS + tuple(range(40 + 7 * i, 52 + 7 * i)),
                max_new_tokens=3, shared_prefix_len=len(ESYS))
        for i in range(N_ADAPTERS)
    ]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode,schedule", MODES)
def test_shared_trunk_token_identical_to_per_adapter_baseline(
        arch, mode, schedule):
    outs = {}
    rates = {}
    for share in (True, False):
        eng = _engine(arch, mode, schedule, share)
        reqs = _workload()
        for r in reqs:
            eng.submit(r)
            eng.run()  # serialize so every later adapter sees a warm trunk
        outs[share] = [tuple(r.generated) for r in reqs]
        rates[share] = eng.manager.stats.kv_hit_rate()
        if share:
            # adapters 1..N-1 hit trunk KV another adapter computed
            assert eng.manager.stats.shared_hit_rate() > 0
            assert all(r.matched_tokens >= (len(ESYS) // 4) * 4
                       for r in reqs[1:])
        else:
            assert eng.manager.stats.shared_hbm_hit_tokens == 0
            assert all(r.matched_tokens == 0 for r in reqs[1:])
        eng.manager.check_invariants()
    assert outs[True] == outs[False], (
        f"{arch}/{mode}/{schedule}: shared-trunk caching changed generation")
    assert rates[True] > rates[False], (
        f"{arch}: sharing must strictly raise the HBM KV hit rate")


def test_shared_and_unshared_agree_under_concurrent_mixed_batches():
    """All adapters in flight at once (chunks + decode rows interleave in
    mixed batches, chunk clamped at the shared boundary)."""
    outs = {}
    for share in (True, False):
        eng = _engine("qwen3-0.6b", "bucketed", "mixed", share)
        reqs = _workload()
        for r in reqs:
            eng.submit(r)
        rep = eng.run()
        assert rep.n_finished == len(reqs)
        outs[share] = [tuple(r.generated) for r in reqs]
        eng.manager.check_invariants()
    assert outs[True] == outs[False]


def test_fully_shared_prompt_and_oversized_declaration():
    """shared_prefix_len >= len(prompt): the whole prompt runs as base rows
    and the first sampled token comes from base logits — identically in
    both configurations."""
    outs = {}
    for share in (True, False):
        eng = _engine("qwen3-0.6b", "bucketed", "mixed", share)
        r1 = Request(f"sp{next(_ids)}", "lora-0", ESYS, max_new_tokens=3,
                     shared_prefix_len=len(ESYS) + 99)
        r2 = Request(f"sp{next(_ids)}", "lora-1", ESYS, max_new_tokens=3,
                     shared_prefix_len=len(ESYS) + 99)
        eng.submit(r1)
        eng.run()
        eng.submit(r2)
        eng.run()
        outs[share] = (tuple(r1.generated), tuple(r2.generated))
        eng.manager.check_invariants()
    assert outs[True] == outs[False]


# --------------------------------------------------- P4: cold-adapter row
def test_shared_span_rows_dispatch_without_adapter_slot():
    eng = _engine("qwen3-0.6b", "bucketed", "mixed", share=True)
    req = Request("cold0", "lora-3", ESYS + (1, 2, 3, 4), max_new_tokens=2,
                  shared_prefix_len=len(ESYS))
    req.phase = Phase.PREFILLING
    req.prefill_pos = 0
    req.slot = 1
    eng._slot_req[1] = req
    assert eng.adapters.slot_of("lora-3") is None  # registered, never loaded
    import numpy as np
    ids = np.asarray(eng._adapter_ids())
    assert ids[1] == -1
    assert eng.adapters.slot_of("lora-3") is None  # no reload was forced
    # past the boundary the row needs (and lazily loads) its adapter
    req.prefill_pos = len(ESYS)
    ids = np.asarray(eng._adapter_ids())
    assert ids[1] >= 0
    assert eng.adapters.slot_of("lora-3") is not None
