"""SLO-tiered admission, cost-model preemption, and request lifecycle.

The key correctness property: a preempted-then-resumed request must emit the
SAME output stream as an unpreempted run. Preemption folds the victim's
computed KV (or recurrent-state snapshot) into the two-tier pool and its
generated tokens into the prompt, so the resume lookup matches the demoted
prefix and decode continues token-identically — across GQA, MLA, and
recurrent-STATE layouts under both mixed and alternate schedules.

Plus the lifecycle bugfixes this area shipped with: ``submit()`` honoring a
caller pre-set ``submit_time`` (trace replay backdating), and ``run()``
draining leftover in-flight requests through the abort path on step
exhaustion instead of leaking their pins/blocks/slots.
"""

import itertools

import jax
import pytest

from repro import configs
from repro.serving import EngineConfig, Phase, Request, ServingEngine
from repro.serving.request import PRIORITY_INTERACTIVE

_ids = itertools.count()

# GQA, MLA, recurrent STATE
ARCHS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "rwkv6-1.6b"]
SCHEDULES = ["mixed", "alternate"]


def make_engine(arch="qwen3-0.6b", schedule="mixed", slots=1, hbm=8 << 20):
    cfg = configs.reduced(configs.get(arch))
    ecfg = EngineConfig(
        hbm_bytes=hbm, host_bytes=32 << 20, block_size=4,
        max_batch_slots=slots, max_seq_len=96, prefill_mode="bucketed",
        prefill_chunk=8, prefill_min_bucket=4,
        schedule_mode=schedule, step_token_budget=24,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(2):
        eng.register_adapter(f"lora-{i}")
    return eng


def req(adapter, prompt, n=4, **kw):
    return Request(f"pp{next(_ids)}", adapter, tuple(prompt),
                   max_new_tokens=n, **kw)


def _step_until(eng, r, phase, limit=64):
    for _ in range(limit):
        if r.phase is phase:
            return
        eng.step()
    raise AssertionError(f"{r.request_id} never reached {phase}")


# ------------------------------------------------- differential: preempt


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("schedule", SCHEDULES)
def test_preempt_resume_token_identical(arch, schedule):
    """Decode-phase preemption: an interactive arrival on a full engine
    evicts the batch-tier victim mid-decode; the victim resumes from its
    swapped KV/state and finishes with an identical output stream."""
    eng = make_engine(arch, schedule)
    victim = req("lora-0", range(10, 26), n=8)
    eng.submit(victim)
    _step_until(eng, victim, Phase.DECODE)
    eng.step()  # generate at least one token to carry across the preempt
    assert victim.generated
    intr = req("lora-1", range(40, 48), n=2,
               priority=PRIORITY_INTERACTIVE, deadline=eng.now() + 0.01)
    eng.submit(intr)
    report = eng.run()
    assert victim.preempt_count >= 1
    assert report.n_preempted >= 1
    assert victim.phase is Phase.FINISHED
    assert intr.phase is Phase.FINISHED
    # the interactive actually jumped the queue
    assert intr.finish_time <= victim.finish_time
    assert len(victim.output_tokens) == 8

    ref_eng = make_engine(arch, schedule)
    ref = req("lora-0", range(10, 26), n=8)
    ref_eng.submit(ref)
    ref_eng.run()
    assert victim.output_tokens == tuple(ref.generated), (
        "preempt/resume changed generation"
    )
    eng.manager.check_invariants()


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "rwkv6-1.6b"])
def test_preempt_mid_prefill_token_identical(arch):
    """Prefill-phase preemption: the victim loses its un-aligned tail (and,
    for recurrent layouts with no crossed capture boundary, the whole
    partial prefill) but must still resume to an identical stream."""
    eng = make_engine(arch, "mixed")
    victim = req("lora-0", range(100, 132), n=4)  # 32 tokens, 4 chunks
    eng.submit(victim)
    _step_until(eng, victim, Phase.PREFILLING)
    assert 0 < victim.prefill_pos < len(victim.prompt)
    intr = req("lora-1", range(40, 48), n=2,
               priority=PRIORITY_INTERACTIVE, deadline=eng.now() + 0.01)
    eng.submit(intr)
    eng.run()
    assert victim.preempt_count >= 1
    assert victim.phase is Phase.FINISHED

    ref_eng = make_engine(arch, "mixed")
    ref = req("lora-0", range(100, 132), n=4)
    ref_eng.submit(ref)
    ref_eng.run()
    assert victim.output_tokens == tuple(ref.generated)
    eng.manager.check_invariants()


def test_double_preempt_resume_token_identical():
    """A victim preempted twice (two interactive waves) still resumes to an
    identical stream, with every wave's tokens accumulated in carried."""
    eng = make_engine()
    victim = req("lora-0", range(10, 26), n=8)
    eng.submit(victim)
    for wave in range(2):
        _step_until(eng, victim, Phase.DECODE)
        eng.step()
        intr = req("lora-1", range(40 + 10 * wave, 48 + 10 * wave), n=2,
                   priority=PRIORITY_INTERACTIVE, deadline=eng.now() + 0.01)
        eng.submit(intr)
        _step_until(eng, intr, Phase.FINISHED)
    report = eng.run()
    assert victim.preempt_count == 2
    assert report.n_preempted == 2
    assert victim.phase is Phase.FINISHED

    ref_eng = make_engine()
    ref = req("lora-0", range(10, 26), n=8)
    ref_eng.submit(ref)
    ref_eng.run()
    assert victim.output_tokens == tuple(ref.generated)
    eng.manager.check_invariants()


def test_preemption_is_priority_strict():
    """Equal-priority arrivals never preempt: the engine falls back to
    waiting for a slot, so a same-tier victim keeps running."""
    eng = make_engine()
    first = req("lora-0", range(10, 22), n=6)
    eng.submit(first)
    _step_until(eng, first, Phase.DECODE)
    peer = req("lora-1", range(40, 48), n=2)  # same (batch) tier
    eng.submit(peer)
    report = eng.run()
    assert first.preempt_count == 0
    assert report.n_preempted == 0
    assert first.finish_time <= peer.first_token_time


def test_interactive_admitted_ahead_of_earlier_batch():
    """A free-slot engine with a queued backlog admits by tier first: the
    later-submitted interactive request overtakes the earlier batch one."""
    eng = make_engine(slots=1)
    running = req("lora-0", range(10, 22), n=6)
    queued_batch = req("lora-0", range(60, 72), n=2)
    eng.submit(running)
    _step_until(eng, running, Phase.DECODE)
    eng.submit(queued_batch)
    intr = req("lora-1", range(40, 48), n=2,
               priority=PRIORITY_INTERACTIVE, deadline=eng.now() + 10.0)
    eng.submit(intr)
    eng.run()
    assert intr.admit_time <= queued_batch.admit_time
    assert intr.first_token_time <= queued_batch.first_token_time


# ------------------------------------------------- lifecycle bugfixes


def test_submit_honors_preset_arrival():
    eng = make_engine()
    backdated = req("lora-0", range(10, 18), n=2, submit_time=123.456)
    eng.submit(backdated)
    assert backdated.submit_time == 123.456
    fresh = req("lora-0", range(20, 28), n=2)
    eng.submit(fresh)
    assert fresh.submit_time is not None
    assert fresh.submit_time != 123.456


def test_run_exhaustion_drains_and_reports():
    """Step-budget exhaustion must release every in-flight resource through
    the abort path and surface the damage in the report — WAITING requests
    hold nothing and stay queued for a later run()."""
    eng = make_engine(slots=2)
    rs = [req("lora-0", range(10 + 16 * i, 26 + 16 * i), n=8)
          for i in range(4)]
    for r in rs:
        eng.submit(r)
    report = eng.run(max_steps=2)
    assert report.n_finished == 0
    assert report.n_unfinished == 4
    assert report.n_aborted == 2  # the two slot-resident requests drained
    for r in eng.aborted:
        assert r.phase is Phase.ABORTED
        assert r.slot == -1 and not r.pinned
        assert r.finish_time is not None
    assert len(eng.waiting) == 2  # untouched, still queued
    eng.manager.check_invariants()
    # the engine is still serviceable: the queued leftovers finish cleanly
    report2 = eng.run()
    assert report2.n_finished == 2
    assert report2.n_unfinished == 0
    eng.manager.check_invariants()


def test_abort_waiting_and_running():
    eng = make_engine(slots=2)
    running = req("lora-0", range(10, 22), n=6)
    waiting = req("lora-1", range(40, 52), n=6)
    eng.submit(running)
    _step_until(eng, running, Phase.DECODE)
    eng.submit(waiting)
    eng.abort(waiting)  # never admitted: just leaves the queue
    assert waiting.phase is Phase.ABORTED
    assert not eng.waiting
    eng.abort(running)  # in-flight: blocks + slot + pins released
    assert running.phase is Phase.ABORTED
    assert running.slot == -1
    report = eng.run()
    assert report.n_finished == 0
    assert report.n_aborted == 2
    eng.manager.check_invariants()
    # aborting twice is a no-op
    eng.abort(running)
    assert len(eng.aborted) == 2


def test_legacy_traces_admit_fcfs():
    """No tiers, no deadlines: the ranked admission must reduce to exact
    FCFS submit order."""
    eng = make_engine(slots=1)
    rs = [req("lora-0", range(10 + 8 * i, 18 + 8 * i), n=2)
          for i in range(4)]
    for r in rs:
        eng.submit(r)
    eng.run()
    admits = [r.admit_time for r in rs]
    assert admits == sorted(admits)
    assert all(r.phase is Phase.FINISHED for r in rs)
