"""Bucketed batch-prefill subsystem tests.

Three properties pin the new hot path (serving/prefill.py) to the seed
eager path:
  P1  bucket math: smallest covering power-of-two bucket, exact at edges
  P2  logits/token equivalence: row-masked bucketed/chunked prefill computes
      the same numbers as exact-shape extend, at model AND engine level
  P3  compile economy: N requests with M distinct suffix lengths lower at
      most len(buckets) distinct shapes (jit tracing-cache probe)
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.serving import EngineConfig, Phase, Request, ServingEngine
from repro.serving.prefill import bucket_for, make_buckets

# ----------------------------------------------------------------- P1: math


def test_make_buckets_powers_of_two():
    assert make_buckets(8, 64) == (8, 16, 32, 64)
    assert make_buckets(4, 4) == (4,)
    # non-power-of-two chunk is kept as the terminal bucket
    assert make_buckets(4, 48) == (4, 8, 16, 32, 48)
    # min > chunk degrades to a single bucket
    assert make_buckets(64, 16) == (16,)


def test_bucket_for_edges():
    buckets = make_buckets(8, 64)
    assert bucket_for(0, buckets) == 8
    assert bucket_for(1, buckets) == 8
    assert bucket_for(8, buckets) == 8  # exact boundary stays in-bucket
    assert bucket_for(9, buckets) == 16
    assert bucket_for(16, buckets) == 16
    assert bucket_for(17, buckets) == 32
    assert bucket_for(64, buckets) == 64
    with pytest.raises(ValueError):
        bucket_for(65, buckets)


# ------------------------------------------------- P2 (model level): masking

ARCHS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "rwkv6-1.6b",
         "recurrentgemma-2b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_row_masked_extend_matches_exact(arch):
    """Padded batched extend (true_lens) must equal per-row exact extend:
    same last-real-token logits and the same subsequent decode step."""
    cfg = configs.reduced(configs.get(arch))
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    B, T, S = 3, 32, 8
    lens = [5, 3, 7]  # < S: every row is padded
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab_size, size=n) for n in lens]

    # row-masked batched path
    cache = model.init_cache(B, T)
    tokens = np.zeros((B, S), np.int32)
    for i, pr in enumerate(prompts):
        tokens[i, : len(pr)] = pr
    true_lens = jnp.asarray(lens, jnp.int32)
    start = jnp.zeros((B,), jnp.int32)
    logits, cache = model.extend(params, cache, jnp.asarray(tokens), start,
                                 all_logits=True, true_lens=true_lens)
    assert np.asarray(cache["len"]).tolist() == lens
    masked_last = np.stack([np.asarray(logits[i, n - 1])
                            for i, n in enumerate(lens)])
    next_tok = jnp.asarray(
        [[int(np.argmax(masked_last[i]))] for i in range(B)], jnp.int32)
    dec_logits, _ = model.decode(params, cache, next_tok)
    # exact-shape reference, one row at a time
    for i, pr in enumerate(prompts):
        ref_cache = model.init_cache(1, T)
        ref_logits, ref_cache = model.extend(
            params, ref_cache, jnp.asarray(pr, jnp.int32)[None, :],
            jnp.zeros((1,), jnp.int32))
        np.testing.assert_allclose(
            masked_last[i], np.asarray(ref_logits[0, -1]),
            rtol=1e-5, atol=1e-5)
        ref_dec, _ = model.decode(params, ref_cache, next_tok[i][None, :])
        np.testing.assert_allclose(
            np.asarray(dec_logits[i, -1]), np.asarray(ref_dec[0, -1]),
            rtol=1e-5, atol=1e-5)


def test_row_masked_extend_on_wrapped_ring_window():
    """Windowed (ring-indexed) caches: once the ring has wrapped, pad slots
    must neither overwrite live window keys nor shadow them in the position
    labeling (the `last real position` anchor in gqa_cached)."""
    cfg = configs.reduced(configs.get("recurrentgemma-2b"))
    W = cfg.window_size  # 16 in the reduced config
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    warm = rng.randint(1, cfg.vocab_size, size=W + 5)  # ring wrapped
    lens = [3, 6]  # second chunk, padded to a shared bucket of 8
    chunks = [rng.randint(1, cfg.vocab_size, size=n) for n in lens]
    B, S = 2, 8
    cache = model.init_cache(B, 64)
    warm2 = jnp.asarray(np.stack([warm, warm]), jnp.int32)
    _, cache = model.extend(params, cache, warm2, jnp.zeros((B,), jnp.int32))
    tokens = np.zeros((B, S), np.int32)
    for i, ch in enumerate(chunks):
        tokens[i, : len(ch)] = ch
    logits, cache = model.extend(
        params, cache, jnp.asarray(tokens), jnp.asarray(cache["len"]),
        all_logits=True, true_lens=jnp.asarray(lens, jnp.int32))
    for i, ch in enumerate(chunks):
        ref_cache = model.init_cache(1, 64)
        _, ref_cache = model.extend(params, ref_cache,
                                    jnp.asarray(warm, jnp.int32)[None, :],
                                    jnp.zeros((1,), jnp.int32))
        ref_logits, _ = model.extend(params, ref_cache,
                                     jnp.asarray(ch, jnp.int32)[None, :],
                                     jnp.asarray(ref_cache["len"]))
        np.testing.assert_allclose(
            np.asarray(logits[i, len(ch) - 1]), np.asarray(ref_logits[0, -1]),
            rtol=1e-4, atol=1e-4)


def test_ring_window_rejects_overwide_masked_chunk():
    """A padded chunk wider than the ring must be refused, not silently
    corrupt the window (duplicate scatter indices)."""
    cfg = configs.reduced(configs.get("recurrentgemma-2b"))
    W = cfg.window_size
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    cache = model.init_cache(1, 64)
    with pytest.raises(ValueError, match="ring window"):
        model.extend(params, cache, jnp.zeros((1, W + 8), jnp.int32),
                     jnp.zeros((1,), jnp.int32), all_logits=True,
                     true_lens=jnp.asarray([W + 2], jnp.int32))


def test_row_masked_rows_ride_along_untouched():
    """Rows with true_lens == 0 must keep cache contents and len exactly."""
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    model = build_model(cfg, dtype=jnp.float32)
    params = model.init_params(jax.random.PRNGKey(0))
    B, T = 2, 32
    cache = model.init_cache(B, T)
    # seed row 1 with some real context first
    warm = jnp.asarray(np.arange(1, 7)[None, :], jnp.int32)
    _, cache = model.extend(params, cache, jnp.vstack([warm, warm]),
                            jnp.zeros((B,), jnp.int32))
    before_k = np.asarray(cache["k"][:, 1])
    _, cache = model.extend(
        params, cache, jnp.zeros((B, 4), jnp.int32), jnp.asarray(cache["len"]),
        all_logits=True, true_lens=jnp.asarray([4, 0], jnp.int32))
    assert int(cache["len"][0]) == 10 and int(cache["len"][1]) == 6
    np.testing.assert_array_equal(before_k, np.asarray(cache["k"][:, 1]))


# ---------------------------------------------- P2/P3 (engine level)

_ids = itertools.count()


def _req(adapter, prompt, n=4):
    return Request(f"pf{next(_ids)}", adapter, tuple(prompt), max_new_tokens=n)


def _engine(mode, chunk=16, min_bucket=4, slots=4):
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(
        hbm_bytes=8 << 20, host_bytes=32 << 20, block_size=4,
        max_batch_slots=slots, max_seq_len=96, prefill_mode=mode,
        prefill_chunk=chunk, prefill_min_bucket=min_bucket,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(3):
        eng.register_adapter(f"lora-{i}")
    return eng


def _workload():
    """Varied suffix lengths (crossing bucket boundaries), multi-LoRA,
    plus one long prompt that must be chunked."""
    reqs = [_req(f"lora-{i % 3}", range(30 + i, 38 + i + 3 * i), n=4)
            for i in range(6)]
    reqs.append(_req("lora-1", range(100, 150), n=4))
    return reqs


def test_bucketed_matches_eager_end_to_end():
    outs = {}
    for mode in ("eager", "bucketed"):
        eng = _engine(mode)
        reqs = _workload()
        for r in reqs:
            eng.submit(r)
        rep = eng.run()
        assert rep.n_finished == len(reqs)
        outs[mode] = [tuple(r.generated) for r in reqs]
    assert outs["eager"] == outs["bucketed"], (
        "bucketed/chunked prefill changed generation")


def test_warm_prefix_reuse_under_bucketed_prefill():
    """FASTLIBRA hit path must stay token-identical under bucketed prefill."""
    eng = _engine("bucketed")
    r1 = _req("lora-0", range(10, 26), n=8)
    eng.submit(r1)
    eng.run()
    follow = r1.full_tokens
    r2 = _req("lora-0", follow, n=4)
    eng.submit(r2)
    eng.run()
    assert r2.matched_tokens > 0
    cold = _engine("bucketed")
    r2c = _req("lora-0", follow, n=4)
    cold.submit(r2c)
    cold.run()
    assert tuple(r2.generated) == tuple(r2c.generated)


def test_compile_count_bounded_by_buckets():
    eng = _engine("bucketed")
    reqs = _workload()  # 7 distinct suffix lengths
    suffix_lens = {len(r.prompt) for r in reqs}
    assert len(suffix_lens) >= 5  # the workload really is heterogeneous
    for r in reqs:
        eng.submit(r)
    rep = eng.run()
    assert rep.n_finished == len(reqs)
    # jit tracing-cache probe: distinct lowered shapes ≤ number of buckets
    assert 0 < eng.prefill.compile_count <= len(eng.prefill.buckets)
    assert rep.prefill_compiles == eng.prefill.compile_count
    assert rep.avg_prefill_batch >= 1.0


def test_requests_coalesce_into_one_prefill_call():
    """All requests admitted in the same step share ONE batched prefill."""
    eng = _engine("bucketed", slots=4)
    reqs = [_req(f"lora-{i % 3}", range(20, 32), n=2) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.prefill.stats.calls == 1
    assert eng.prefill.stats.rows == 4


def test_chunked_prefill_interleaves_with_decode():
    """A long prompt must not hold the decode loop hostage: short requests
    keep generating while the long prompt is still prefilling."""
    eng = _engine("bucketed", chunk=8)
    short = _req("lora-0", range(10, 20), n=8)
    eng.submit(short)
    eng.step()  # short admitted, prefilled (10 ≤ 2 chunks), starts decoding
    long = _req("lora-1", range(100, 164), n=2)  # 64 tokens = 8 chunks
    eng.submit(long)
    interleaved = 0
    for _ in range(4):
        before = len(short.generated)
        eng.step()
        if long.phase is Phase.PREFILLING and len(short.generated) > before:
            interleaved += 1
    assert interleaved > 0, "decode starved during chunked prefill"
    eng.run()
    assert long.phase is Phase.FINISHED and short.phase is Phase.FINISHED
    assert long.prefill_chunks >= 8
