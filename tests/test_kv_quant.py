"""int8 KV-cache quantization: accuracy + greedy-token preservation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import build_model, make_train_state
from repro.models.attention import quantize_kv_rows


def test_quantize_roundtrip_accuracy():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32), jnp.float32)
    q, s = quantize_kv_rows(x)
    deq = q.astype(jnp.float32) * s[..., None]
    err = jnp.max(jnp.abs(deq - x) / (jnp.max(jnp.abs(x)) + 1e-9))
    assert float(err) < 0.01  # <1% of dynamic range per row


def test_int8_cache_decode_matches_fp():
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    fp = build_model(cfg, dtype=jnp.float32)
    q8 = build_model(cfg, dtype=jnp.float32, kv_quant=True)
    state = make_train_state(fp, jax.random.PRNGKey(0), n_lora_slots=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    ids = jnp.array([0, 1], jnp.int32)

    lf, cf = fp.prefill(state.params, tokens, max_len=24, lora=state.lora,
                        adapter_ids=ids)
    lq, cq = q8.prefill(state.params, tokens, max_len=24, lora=state.lora,
                        adapter_ids=ids)
    assert cq["k"].dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lf), rtol=0.05, atol=0.05)

    # greedy decode path: token-identical for several steps
    tf = jnp.argmax(lf[:, -1], -1).astype(jnp.int32)
    tq = jnp.argmax(lq[:, -1], -1).astype(jnp.int32)
    assert (tf == tq).all()
    for _ in range(6):
        lf, cf = fp.decode(state.params, cf, tf[:, None], lora=state.lora,
                           adapter_ids=ids)
        lq, cq = q8.decode(state.params, cq, tq[:, None], lora=state.lora,
                           adapter_ids=ids)
        tf = jnp.argmax(lf[:, -1], -1).astype(jnp.int32)
        tq = jnp.argmax(lq[:, -1], -1).astype(jnp.int32)
        assert (tf == tq).all(), "int8 KV changed the greedy tokens"


def test_int8_cache_halves_bytes():
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    fp = build_model(cfg, dtype=jnp.bfloat16)
    q8 = build_model(cfg, dtype=jnp.bfloat16, kv_quant=True)
    cf = fp.init_cache(4, 64)
    cq = q8.init_cache(4, 64)
    bytes_fp = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cf))
    bytes_q8 = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cq))
    assert bytes_q8 < bytes_fp * 0.7  # int8 payload + small f32 scales
