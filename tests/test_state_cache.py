"""Recurrent-state prefix-cache subsystem tests.

Four pinned layers:
  T1  data plane (kvcache/state_cache.py): dtype-parameterized footprint,
      store/load bounds-checks, flatten/unflatten roundtrip on a real model
      cache pytree
  T2  control plane (core/cache_manager.py): snapshot match (deepest payload
      node, hollow split interiors skipped), admit pins, evict/swap-in
      roundtrip through the host tier, commit_state dedupe + ablation gates,
      hbm_breakdown accounting
  T3  end-to-end differential: snapshot-resumed decode is token-identical to
      cold-prefix decode for RWKV-6 and RG-LRU under BOTH schedule modes
      (plus the eager correctness pin), with state_hit_rate > 0
  T4  the host-tier roundtrip end-to-end: a snapshot evicted to host swaps
      back in on the next hit and still resumes token-identically
"""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import NodeKind, Residency, make_fastlibra
from repro.kvcache import (
    StateCache,
    StateSpec,
    flat_state_elems,
    flatten_state,
    state_floats,
    unflatten_state,
)
from repro.serving import EngineConfig, Request, ServingEngine

# ---------------------------------------------------------- T1: data plane


def test_state_spec_dtype_parameterizes_footprint():
    f32 = StateSpec(state_elems=1000, block_bytes=1024, dtype=jnp.float32)
    bf16 = StateSpec(state_elems=1000, block_bytes=1024, dtype=jnp.bfloat16)
    assert f32.snapshot_bytes == 4000 and bf16.snapshot_bytes == 2000
    # the forced-f32 bug: a bf16 cache must NOT account at 2x its true size
    assert f32.blocks_per_snapshot == 4 and bf16.blocks_per_snapshot == 2


def test_store_load_roundtrip_and_bounds_checks():
    spec = StateSpec(state_elems=100, block_bytes=64, dtype=jnp.float32)
    cache = StateCache(spec, n_hbm_blocks=24, n_host_blocks=16)
    blocks = list(range(3, 3 + spec.blocks_per_snapshot))
    flat = jnp.arange(100, dtype=jnp.float32)
    cache.store(blocks, flat)
    out = cache.load(blocks, 100)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(flat))
    # load beyond the stored snapshot's block capacity must fail loudly
    with pytest.raises(ValueError):
        cache.load(blocks[:1], 100)
    with pytest.raises(ValueError):
        cache.store(blocks[:1], flat)  # snapshot larger than the blocks
    with pytest.raises(ValueError):
        cache.store([], flat)
    # host-tier roundtrip preserves values
    cache.swap_out(blocks, [0, 1, 2, 3, 4, 5, 6][: len(blocks)])
    cache2_blocks = list(range(10, 10 + len(blocks)))
    cache.swap_in(list(range(len(blocks))), cache2_blocks)
    np.testing.assert_array_equal(
        np.asarray(cache.load(cache2_blocks, 100)), np.asarray(flat))


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
def test_flatten_unflatten_roundtrip(arch):
    from repro.models import build_model

    cfg = configs.reduced(configs.get(arch))
    model = build_model(cfg, dtype=jnp.float32)
    cache = model.init_cache(3, 32)
    n = flat_state_elems(cache)
    assert n == flat_state_elems(jax.eval_shape(lambda: model.init_cache(3, 32)))
    rng = np.random.RandomState(0)
    flat = jnp.asarray(rng.randn(n).astype(np.float32))
    cache2 = unflatten_state(cache, 1, flat)
    back = flatten_state(cache2, 1, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(flat))
    # other rows untouched
    np.testing.assert_array_equal(
        np.asarray(flatten_state(cache2, 0)), np.asarray(flatten_state(cache, 0)))
    # wrong-size snapshot fails loudly
    with pytest.raises(ValueError):
        unflatten_state(cache, 0, flat[:-1])


def test_state_floats_counts_rglru_window_kv():
    cfg = configs.reduced(configs.get("recurrentgemma-2b"))
    with_window = state_floats(cfg)
    without = state_floats(cfg, window=0)
    # the hybrid's local-attention window K/V must be part of the snapshot
    assert with_window > without > 0


# ------------------------------------------------------- T2: control plane

KVB = 64
BS = 4
BLOCK_BYTES = KVB * BS
STATE_BYTES = 2 * BLOCK_BYTES  # one snapshot = 2 pool blocks


def _mgr(hbm_blocks=32, host_blocks=64, variant="fastlibra"):
    mgr, sw = make_fastlibra(
        hbm_bytes=hbm_blocks * BLOCK_BYTES,
        host_bytes=host_blocks * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        variant=variant,
        state_bytes=STATE_BYTES,
    )
    mgr.register_lora("a", BLOCK_BYTES, now=0.0)
    # bring the LoRA into HBM (as engine admission does): a snapshot's
    # ancestry must be HBM-resident or commit_state drops it by design
    lk = mgr.lookup_state("a", (), now=0.5)
    adm = mgr.admit(lk, now=0.5)
    mgr.unpin(adm.pinned)
    mgr.drain_ops()
    return mgr, sw


def test_commit_state_and_deepest_snapshot_match():
    mgr, _ = _mgr()
    toks = tuple(range(40))
    n10 = mgr.commit_state("a", toks[:10], now=1.0)
    assert n10 is not None and n10.kind is NodeKind.STATE
    assert len(n10.hbm_blocks) == mgr.config.state_blocks == 2
    n25 = mgr.commit_state("a", toks[:25], now=2.0)
    assert n25 is not None and n25.parent is n10
    # deepest snapshot at or below the prompt
    lk = mgr.lookup_state("a", toks[:30], now=3.0)
    assert lk.state_node is n25 and lk.state_tokens == 25
    # shorter history resumes from the shallower snapshot
    lk = mgr.lookup_state("a", toks[:17], now=4.0)
    assert lk.state_node is n10 and lk.state_tokens == 10
    # re-committing an existing boundary is a no-op (payload already there)
    assert mgr.commit_state("a", toks[:25], now=5.0) is None
    mgr.check_invariants()


def test_hollow_split_interiors_are_not_resume_points():
    mgr, _ = _mgr()
    base = tuple(range(20))
    assert mgr.commit_state("a", base, now=1.0) is not None
    # diverge after 12 tokens: the radix split must yield a hollow interior
    other = base[:12] + tuple(range(100, 110))
    lk = mgr.lookup_state("a", other, now=2.0)
    assert lk.state_node is None and lk.state_tokens == 0
    assert lk.match.matched_tokens == 12  # structure matched, no payload
    # snapshot the diverged branch; both boundaries now resumable
    assert mgr.commit_state("a", other, now=3.0) is not None
    assert mgr.lookup_state("a", base + (7,), now=4.0).state_tokens == 20
    assert mgr.lookup_state("a", other + (7,), now=5.0).state_tokens == len(other)
    # the hollow interior carries no blocks but keeps the trie radix-correct
    hollow = [n for n in mgr.tree.iter_nodes({NodeKind.STATE})
              if not n.has_payload]
    assert hollow and all(n.num_blocks == 0 for n in hollow)
    mgr.check_invariants()


def test_snapshot_evict_swapin_roundtrip_and_pinning():
    mgr, _ = _mgr(hbm_blocks=8)  # LoRA(1) + 3 snapshots fill HBM
    toks = tuple(range(60))
    mgr.commit_state("a", toks[:10], now=1.0)
    lk = mgr.lookup_state("a", toks[:10], now=1.5)
    adm = mgr.admit(lk, now=1.5)
    assert lk.state_node is not None and lk.state_node.ref_count > 0
    # pinned snapshots are not eviction candidates
    assert lk.state_node not in mgr.evict_candidates()
    mgr.unpin(adm.pinned)
    # evict the snapshot to host
    node = lk.state_node
    mgr._swap_out_node(node, now=2.0)
    assert node.tier is Residency.HOST and node.host_blocks
    # next lookup lists it for swap-in; admit restores HBM residency
    lk2 = mgr.lookup_state("a", toks[:30], now=3.0)
    assert lk2.state_node is node and node in lk2.swap_in_nodes
    assert lk2.hbm_hit_tokens == 0 and lk2.host_hit_tokens == 10
    adm2 = mgr.admit(lk2, now=3.0)
    assert not adm2.queued and node.tier is Residency.HBM
    ops = [o for o in mgr.drain_ops() if o.node_kind is NodeKind.STATE]
    assert any(o.kind.value == "in" for o in ops)
    mgr.unpin(adm2.pinned)
    mgr.check_invariants()


def test_state_breakdown_and_ablation_gates():
    mgr, _ = _mgr()
    mgr.commit_state("a", tuple(range(10)), now=1.0)
    bd = mgr.hbm_breakdown()
    assert bd["state_snapshot_bytes"] == STATE_BYTES
    assert bd["history_kv_bytes"] == 0
    # S-LoRA ablation (no history reuse) never caches snapshots
    slora, _ = _mgr(variant="slora")
    assert slora.commit_state("a", tuple(range(10)), now=1.0) is None
    # state caching off (attention archs): lookup_state finds nothing and
    # commit_state is inert
    plain, _ = make_fastlibra(
        hbm_bytes=32 * BLOCK_BYTES, host_bytes=64 * BLOCK_BYTES,
        kv_bytes_per_token=KVB, block_size=BS)
    plain.register_lora("a", BLOCK_BYTES, now=0.0)
    assert plain.commit_state("a", tuple(range(10)), now=1.0) is None


def test_state_hit_rate_stats_symmetry():
    mgr, _ = _mgr()
    toks = tuple(range(21))
    mgr.commit_state("a", toks[:20], now=1.0)
    mgr.lookup_state("a", toks, now=2.0)  # hit: 20 of 21 tokens
    mgr.lookup_state("a", tuple(range(500, 510)), now=3.0)  # miss
    s = mgr.stats
    # 3 lookups: the _mgr helper's empty-history LoRA admit plus the two here
    assert s.state_lookups == 3 and s.state_hits == 1
    assert s.state_hit_tokens == 20 and s.history_tokens == 31
    assert 0.0 < s.state_hit_rate() < 1.0
    assert s.kv_hit_rate() == 0.0  # KV counters untouched by state lookups


# ------------------------------------------- T3: end-to-end differentials

_ids = itertools.count()


def _engine(arch, schedule, mode="bucketed", hbm=8 << 20):
    cfg = configs.reduced(configs.get(arch))
    ecfg = EngineConfig(
        hbm_bytes=hbm, host_bytes=32 << 20, block_size=4,
        max_batch_slots=4, max_seq_len=96, prefill_mode=mode,
        prefill_chunk=8, prefill_min_bucket=4,
        schedule_mode=schedule, step_token_budget=24,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(2):
        eng.register_adapter(f"lora-{i}")
    return eng


def _req(prompt, adapter="lora-0", n=4):
    return Request(f"st{next(_ids)}", adapter, tuple(prompt), max_new_tokens=n)


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
@pytest.mark.parametrize("schedule", ["mixed", "alternate"])
def test_snapshot_resume_token_identical(arch, schedule):
    """Differential: a repeated prompt resumes from the snapshot (warm) and
    must generate exactly the cold run's tokens."""
    eng = _engine(arch, schedule)
    prompt = tuple(range(30, 55))
    cold = _req(prompt)
    eng.submit(cold)
    eng.run()
    assert cold.matched_tokens == 0  # first occurrence is a cold prefix
    warm = _req(prompt)
    eng.submit(warm)
    rep = eng.run()
    assert warm.matched_tokens == len(prompt) - 1, "snapshot not resumed"
    assert tuple(warm.generated) == tuple(cold.generated), (
        f"{arch}/{schedule}: snapshot resume changed generation")
    assert rep.state_hit_rate > 0
    assert rep.kv_hit_rate == 0.0
    eng.manager.check_invariants()


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-2b"])
def test_snapshot_resume_matches_eager_pin(arch):
    """The eager path (two-span capture) and the bucketed path must agree on
    both the cold and the resumed generation."""
    outs = {}
    for mode, schedule in (("eager", "alternate"), ("bucketed", "mixed")):
        eng = _engine(arch, schedule, mode=mode)
        prompt = tuple(range(10, 43))
        r1, r2 = _req(prompt), _req(prompt)
        eng.submit(r1)
        eng.run()
        eng.submit(r2)
        eng.run()
        assert r2.matched_tokens == len(prompt) - 1
        outs[mode] = (tuple(r1.generated), tuple(r2.generated))
    assert outs["eager"] == outs["bucketed"]


def test_conversation_continuation_resumes_prefix():
    """Multi-turn reuse: turn 2's prompt extends turn 1's — it must resume
    from turn 1's boundary snapshot and only prefill the continuation."""
    eng = _engine("rwkv6-1.6b", "mixed")
    turn1 = tuple(range(100, 130))
    r1 = _req(turn1)
    eng.submit(r1)
    eng.run()
    turn2 = turn1 + tuple(r1.generated) + tuple(range(200, 210))
    r2 = _req(turn2)
    eng.submit(r2)
    eng.run()
    assert r2.matched_tokens == len(turn1) - 1
    # reference: the same two turns on a fresh engine with no reuse possible
    ref = _engine("rwkv6-1.6b", "mixed")
    q2 = _req(turn2)
    ref.submit(q2)
    ref.run()
    assert tuple(r2.generated) == tuple(q2.generated)


def test_snapshot_survives_host_roundtrip_end_to_end():
    """T4: evict the committed snapshot to the host tier, then hit it — the
    engine must swap it back through StateCache and still decode the cold
    run's tokens, charging the transfer as kv_coldstart."""
    eng = _engine("rwkv6-1.6b", "mixed")
    prompt = tuple(range(60, 88))
    cold = _req(prompt)
    eng.submit(cold)
    eng.run()
    mgr = eng.manager
    snap = [n for n in mgr.tree.iter_nodes({NodeKind.STATE}) if n.has_payload]
    assert len(snap) == 1
    mgr._swap_out_node(snap[0], now=eng._now())
    eng._execute_swaps(mgr.drain_ops())
    assert snap[0].tier is Residency.HOST
    # the idle prefetch sweep would race the admission: with HBM usage under
    # the lower threshold, the swapper's next tick could swap the snapshot
    # back BEFORE the warm request looks it up, so no SWAP_IN lands on its
    # critical path and kv_coldstart is (flakily) 0. Pin the scenario: the
    # hit must demand-page the snapshot in.
    eng.swapper.config.enabled = False
    warm = _req(prompt)
    eng.submit(warm)
    eng.run()
    assert warm.matched_tokens == len(prompt) - 1
    assert tuple(warm.generated) == tuple(cold.generated)
    assert warm.kv_coldstart > 0  # the swap-in landed on its critical path
    eng.manager.check_invariants()
