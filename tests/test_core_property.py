"""Property-based tests (hypothesis) for FASTLIBRA system invariants.

Invariants under arbitrary workloads:
  I1  validity: HBM node ⇒ parent HBM (zero invalid KVs) for FastLibra
  I2  block-pool conservation: free + allocated == total, no double-booking
  I3  radix property: sibling edges never share an align-chunk prefix
  I4  matched tokens are always a prefix of the query and align-quantized
  I5  byte accounting: Σ node bytes are preserved across splits
"""

import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import (
    DependencyTree,
    NodeKind,
    Residency,
    make_fastlibra,
)

KVB = 64
BS = 4
BLOCK_BYTES = KVB * BS

tokens_st = st.lists(st.integers(0, 7), min_size=0, max_size=24).map(tuple)
lora_st = st.sampled_from(["a", "b", "c"])


@given(st.lists(st.tuples(lora_st, tokens_st), min_size=1, max_size=30))
@settings(max_examples=200, deadline=None)
def test_tree_properties(inserts):
    t = DependencyTree(align=1, decay_tau=0.0)
    for lid in "abc":
        t.add_lora(lid, 10, 1, tier=Residency.HBM)
    stored: dict[str, set[tuple]] = {"a": set(), "b": set(), "c": set()}
    for lid, toks in inserts:
        if not toks:
            continue
        m = t.match(lid, toks, now=1.0)
        # I4: match result is a true prefix
        assert m.matched_tokens <= len(toks)
        path = m.last_node.path_tokens()
        assert path == toks[: m.matched_tokens]
        suffix = toks[m.matched_tokens :]
        if suffix:
            t.insert_kv(m.last_node, suffix, len(suffix) * KVB, 1, Residency.HBM, 1.0)
        stored[lid].add(toks)
    # I5: tree bytes == union-of-prefixes bytes per lora branch
    for lid, seqs in stored.items():
        prefix_tokens = set()
        for s in seqs:
            for i in range(1, len(s) + 1):
                prefix_tokens.add(s[:i])
        lnode = t.lora_node(lid)
        tree_bytes = _subtree_bytes(lnode)
        assert tree_bytes == len(prefix_tokens) * KVB
        # I3: sibling edges diverge on the first token
        _check_radix(lnode)
        # every stored sequence must now fully match
        for s in seqs:
            m = t.match(lid, s, now=2.0)
            assert m.matched_tokens == len(s)
    t.check_validity_invariant()


def _subtree_bytes(node):
    out = 0
    stack = list(node.children.values())
    while stack:
        n = stack.pop()
        out += n.size_bytes
        stack.extend(n.children.values())
    return out


def _check_radix(node):
    stack = [node]
    while stack:
        n = stack.pop()
        firsts = [c.tokens[0] for c in n.children.values() if c.tokens]
        assert len(firsts) == len(set(firsts)), "sibling edges share a first token"
        stack.extend(n.children.values())


op_st = st.one_of(
    st.tuples(st.just("query"), lora_st, tokens_st, st.integers(1, 20)),
    st.tuples(st.just("tick"), st.floats(0.1, 5.0)),
)

# I6: the abort/evict interleaving — queries stay OPEN (pinned, holding
# running blocks) across other queries' lifecycles, then commit or abort in
# arbitrary order, with swapper sweeps in between. Nothing before this
# fuzzed partially-completed queries racing the eviction machinery.
# The preempt/resume pair folds an open query's computed prefix back into
# the tree (preempt_running) and later re-admits it under the SAME query id
# (the engine's swap-out-and-requeue path) — the sanitizer's
# preempted-residue family must hold across every interleaving.
mixed_op_st = st.one_of(
    # begin carries a declared shared-prefix length: 0 = plain per-adapter
    # query, >0 = the leading span commits to the cross-adapter trunk, so
    # trunk inserts/splits/forks interleave with every other op family
    st.tuples(st.just("begin"), lora_st, tokens_st, st.integers(1, 16),
              st.integers(0, 16)),
    st.tuples(st.just("grow"), st.integers(0, 7), st.integers(1, 8)),
    st.tuples(st.just("commit"), st.integers(0, 7)),
    st.tuples(st.just("abort"), st.integers(0, 7)),
    # discard=True exercises the no-reusable-prefix branch (lookup=None)
    st.tuples(st.just("preempt"), st.integers(0, 7), st.booleans()),
    st.tuples(st.just("resume"), st.integers(0, 7)),
    st.tuples(st.just("tick"), st.floats(0.1, 5.0), st.floats(0.0, 24.0)),
)


def _check_breakdown(mgr, hbm_bytes):
    """Byte-accounting exactness: the hbm_breakdown() category sums must
    equal the block pool's used bytes EXACTLY — not merely stay under
    capacity. Any drift (a leaked block, a double-count across categories)
    shows up as an inequality here at the op that introduced it."""
    bd = mgr.hbm_breakdown()
    used = (bd["lora_bytes"] + bd["history_kv_bytes"] + bd["shared_kv_bytes"]
            + bd["state_snapshot_bytes"] + bd["running_kv_bytes"])
    pool_used = mgr.pool.stats().hbm_used * mgr.config.block_bytes
    assert used == pool_used, (bd, pool_used)
    assert used <= bd["total_bytes"], bd
    assert bd["total_bytes"] <= hbm_bytes, bd


@given(st.lists(mixed_op_st, min_size=1, max_size=40), st.integers(8, 32))
@settings(max_examples=100, deadline=None)
def test_manager_invariants_with_open_queries(ops, hbm_blocks):
    hbm_bytes = hbm_blocks * BLOCK_BYTES
    mgr, sw = make_fastlibra(
        hbm_bytes=hbm_bytes,
        host_bytes=128 * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        sanitize=True,  # full libra-check sweep after EVERY mutating op
    )
    for lid in "abc":
        mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
    now = 1.0
    qid = 0
    open_queries: list[dict] = []  # admitted, pinned, not yet resolved
    preempted: list[dict] = []  # swapped out, holding NOTHING, resumable
    for op in ops:
        now += 0.05
        if op[0] == "begin":
            _, lid, toks, new_toks, shared = op
            lk = mgr.lookup(lid, toks, now, shared_prefix_len=shared)
            adm = mgr.admit(lk, now)
            if adm.queued:
                mgr.drain_ops()
            else:
                name = f"m{qid}"
                qid += 1
                need = len(toks) - lk.match.matched_tokens + new_toks
                blocks = mgr.allocate_running(name, need, now)
                if blocks is None:
                    mgr.abort_running(name)
                    mgr.unpin(adm.pinned)
                else:
                    open_queries.append({
                        "id": name, "lid": lid, "lookup": lk,
                        "pinned": adm.pinned,
                        "toks": tuple(toks), "new": new_toks,
                    })
        elif op[0] == "grow" and open_queries:
            q = open_queries[op[1] % len(open_queries)]
            got = mgr.allocate_running(q["id"], op[2], now)
            if got is not None:
                q["new"] += op[2]
        elif op[0] == "commit" and open_queries:
            q = open_queries.pop(op[1] % len(open_queries))
            full = q["toks"] + tuple(
                range(1000 + qid * 100, 1000 + qid * 100 + q["new"]))
            mgr.commit(q["id"], q["lookup"], full, now)
            mgr.unpin(q["pinned"])
        elif op[0] == "abort" and open_queries:
            q = open_queries.pop(op[1] % len(open_queries))
            mgr.abort_running(q["id"])
            mgr.unpin(q["pinned"])
        elif op[0] == "preempt" and open_queries:
            q = open_queries.pop(op[1] % len(open_queries))
            # the engine folds the computed prefix (prompt + generated so
            # far) back into the tree; discard=True models the
            # nothing-reusable branch (recurrent layout with an uncrossed
            # capture boundary → lookup=None → plain abort + mark)
            done = q["new"] // 2
            computed = q["toks"] + tuple(
                range(3000 + qid * 100, 3000 + qid * 100 + done))
            if op[2]:
                mgr.preempt_running(q["id"], None, (), now)
            else:
                mgr.preempt_running(q["id"], q["lookup"], computed, now)
            mgr.unpin(q["pinned"])
            preempted.append({"id": q["id"], "lid": q["lid"],
                              "toks": computed})
        elif op[0] == "resume" and preempted:
            # re-admit under the SAME query id: allocate_running must clear
            # the preempted-residue mark, and the lookup should find the
            # victim's own folded prefix
            p = preempted.pop(op[1] % len(preempted))
            lk = mgr.lookup(p["lid"], p["toks"], now)
            adm = mgr.admit(lk, now)
            if adm.queued:
                mgr.drain_ops()
                preempted.append(p)  # retry in a later op
            else:
                need = len(p["toks"]) - lk.match.matched_tokens + 2
                blocks = mgr.allocate_running(p["id"], need, now)
                if blocks is None:
                    mgr.abort_running(p["id"])
                    mgr.unpin(adm.pinned)
                else:
                    open_queries.append({
                        "id": p["id"], "lid": p["lid"], "lookup": lk,
                        "pinned": adm.pinned,
                        "toks": p["toks"], "new": 2,
                    })
        elif op[0] == "tick":
            sw.observe_batch_size(op[2])  # unified token-count signal
            sw.tick(now + op[1])
            mgr.drain_ops()
        # I1 + I2 + I6 after every operation
        mgr.check_invariants()
        _check_breakdown(mgr, hbm_bytes)
    # resolve stragglers both ways, then nothing may stay pinned
    for i, q in enumerate(open_queries):
        if i % 2 == 0:
            mgr.abort_running(q["id"])
        else:
            full = q["toks"] + tuple(range(2000, 2000 + q["new"]))
            mgr.commit(q["id"], q["lookup"], full, now)
        mgr.unpin(q["pinned"])
        mgr.check_invariants()
        _check_breakdown(mgr, hbm_bytes)
    for n in mgr.tree.iter_nodes():
        assert n.ref_count == 0
    assert mgr.invalid_kv_fraction() == 0.0


# I7: recurrent-state snapshot nodes (NodeKind.STATE) interleaved with LoRA
# and KV ops in ONE unified pool — snapshots are fixed-size and indivisible,
# radix splits leave hollow interiors carrying nothing, and the pool /
# validity / breakdown invariants must hold across arbitrary
# lookup_state/admit/commit_state/evict/swap interleavings. KV branches live
# under LoRAs "a"/"b" and snapshot branches under "c"/"d" (one cache layout
# per adapter deployment — the trie/eviction machinery is shared).
state_mixed_op_st = st.one_of(
    st.tuples(st.just("kv"), st.sampled_from(["a", "b"]), tokens_st,
              st.integers(1, 12), st.integers(0, 12)),
    st.tuples(st.just("snap"), st.sampled_from(["c", "d"]), tokens_st),
    st.tuples(st.just("slookup"), st.sampled_from(["c", "d"]), tokens_st),
    st.tuples(st.just("tick"), st.floats(0.1, 5.0), st.floats(0.0, 24.0)),
)

STATE_BYTES = 2 * BLOCK_BYTES  # one snapshot = 2 unified-pool blocks


@given(st.lists(state_mixed_op_st, min_size=1, max_size=40),
       st.integers(10, 32))
@settings(max_examples=100, deadline=None)
def test_state_nodes_interleaved_with_kv_and_lora_ops(ops, hbm_blocks):
    hbm_bytes = hbm_blocks * BLOCK_BYTES
    mgr, sw = make_fastlibra(
        hbm_bytes=hbm_bytes,
        host_bytes=128 * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        state_bytes=STATE_BYTES,
        sanitize=True,
    )
    for lid in "abcd":
        mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
    now = 1.0
    qid = 0
    for op in ops:
        now += 0.05
        if op[0] == "kv":
            _, lid, toks, new_toks, shared = op
            lk = mgr.lookup(lid, toks, now, shared_prefix_len=shared)
            adm = mgr.admit(lk, now)
            if adm.queued:
                mgr.drain_ops()
            else:
                qid += 1
                need = len(toks) - lk.match.matched_tokens + new_toks
                blocks = mgr.allocate_running(f"s{qid}", need, now)
                if blocks is None:
                    mgr.abort_running(f"s{qid}")
                else:
                    full = tuple(toks) + tuple(
                        range(100 + qid * 50, 100 + qid * 50 + new_toks))
                    mgr.commit(f"s{qid}", lk, full, now)
                mgr.unpin(adm.pinned)
        elif op[0] == "snap" and op[2]:
            _, lid, toks = op
            lk = mgr.lookup_state(lid, toks, now)
            adm = mgr.admit(lk, now)
            if adm.queued:
                mgr.drain_ops()
            else:
                # an admitted query captures a snapshot at its full boundary
                node = mgr.commit_state(lid, toks, now)
                if node is not None:
                    assert node.has_payload
                    assert node.num_blocks == mgr.config.state_blocks
                mgr.unpin(adm.pinned)
        elif op[0] == "slookup":
            _, lid, toks = op
            lk = mgr.lookup_state(lid, toks, now)
            # a resumable snapshot is never a hollow interior
            if lk.state_node is not None:
                assert lk.state_node.has_payload
                assert 0 < lk.state_tokens <= len(toks)
            adm = mgr.admit(lk, now)
            if not adm.queued:
                if lk.state_node is not None:
                    from repro.core import Residency as R
                    assert lk.state_node.tier is R.HBM  # admit swapped it in
                mgr.unpin(adm.pinned)
            mgr.drain_ops()
        elif op[0] == "tick":
            sw.observe_batch_size(op[2])
            sw.tick(now + op[1])
            mgr.drain_ops()
        mgr.check_invariants()
        _check_breakdown(mgr, hbm_bytes)
    # terminal structure: no pins; snapshot payloads are whole (exactly
    # state_blocks in exactly one tier) and hollow interiors own nothing
    for n in mgr.tree.iter_nodes():
        assert n.ref_count == 0
        if n.kind is NodeKind.STATE:
            if n.has_payload:
                assert not (n.hbm_blocks and n.host_blocks)
                assert len(n.hbm_blocks or n.host_blocks) == mgr.config.state_blocks
            else:
                assert n.num_blocks == 0 or n.tier is None
    assert mgr.invalid_kv_fraction() == 0.0


@given(st.lists(op_st, min_size=1, max_size=40), st.integers(8, 32))
@settings(max_examples=100, deadline=None)
def test_manager_invariants_under_workload(ops, hbm_blocks):
    mgr, sw = make_fastlibra(
        hbm_bytes=hbm_blocks * BLOCK_BYTES,
        host_bytes=128 * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        sanitize=True,
    )
    for lid in "abc":
        mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
    now = 1.0
    qid = 0
    for op in ops:
        now += 0.05
        if op[0] == "query":
            _, lid, toks, new_toks = op
            lk = mgr.lookup(lid, toks, now)
            adm = mgr.admit(lk, now)
            if adm.queued:
                continue
            need = len(toks) - lk.match.matched_tokens + new_toks
            blocks = mgr.allocate_running(f"q{qid}", need, now)
            if blocks is None:
                mgr.abort_running(f"q{qid}")
                mgr.unpin(adm.pinned)
                qid += 1
                continue
            full = tuple(toks) + tuple(range(100 + qid, 100 + qid + new_toks))
            mgr.commit(f"q{qid}", lk, full, now)
            mgr.unpin(adm.pinned)
            qid += 1
        else:
            sw.observe_batch_size(2.0)
            sw.tick(now + op[1])
        # I1 + I2 after every operation
        mgr.check_invariants()
    # no pins should remain
    for n in mgr.tree.iter_nodes():
        assert n.ref_count == 0
    assert mgr.invalid_kv_fraction() == 0.0
