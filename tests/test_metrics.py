"""Unit tests for serving metrics aggregation (serving/metrics.py).

Percentile edge cases, ``ServingReport.row()`` round-tripping (the contract
the unified benchmark emitter in benchmarks/common.py builds on), and
``summarize()`` over partially-populated requests — finished requests that
never recorded a TPOT (single-token decodes) or a queue time must not crash
or skew the aggregates.
"""

from repro.serving.metrics import ServingReport, _p, summarize
from repro.serving.request import Request


def _req(rid, submit=0.0, admit=None, first=None, finish=None, tokens=()):
    r = Request(rid, "lora-0", (1, 2, 3), max_new_tokens=4)
    r.submit_time = submit
    r.admit_time = admit
    r.first_token_time = first
    r.finish_time = finish
    r.generated = list(tokens)
    return r


# ------------------------------------------------------------------ _p
def test_percentile_empty_is_zero():
    assert _p([], 0.5) == 0.0
    assert _p([], 0.99) == 0.0


def test_percentile_single_element():
    assert _p([3.25], 0.0) == 3.25
    assert _p([3.25], 0.5) == 3.25
    assert _p([3.25], 0.99) == 3.25
    assert _p([3.25], 1.0) == 3.25


def test_percentile_bounds_and_order_independence():
    vals = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert _p(vals, 0.0) == 1.0  # q=0 -> minimum
    assert _p(vals, 1.0) == 5.0  # q=1 clamps to the maximum
    assert _p(vals, 0.5) == _p(sorted(vals), 0.5)
    assert min(vals) <= _p(vals, 0.99) <= max(vals)


# ------------------------------------------------------- row round-trip
def test_report_row_round_trip():
    rep = ServingReport(
        n_finished=3, avg_ttft=0.5, p99_ttft=0.9, avg_tpot=0.01,
        avg_queue=0.1, avg_lora_coldstart=0.02, avg_kv_coldstart=0.03,
        throughput_qps=2.0, kv_hit_rate=0.4, lora_hit_rate=0.6,
        invalid_kv_fraction=0.0, hbm_utilization=0.7,
        ttft_pred_mae=0.005, ttft_pred_bias=-0.001,
    )
    row = rep.row()
    assert isinstance(row, dict)
    assert ServingReport(**row) == rep
    # every dataclass field is present in the row (the benchmark emitter's
    # field-selection contract)
    assert set(row) == set(ServingReport.__dataclass_fields__)


# ------------------------------------------------------------ summarize
def test_summarize_skips_requests_without_first_token():
    done = _req("a", submit=0.0, admit=0.5, first=1.0, finish=2.0,
                tokens=(7, 8, 9))
    never_started = _req("b")  # no first token: excluded everywhere
    rep = summarize([done, never_started], wall_time=2.0)
    assert rep.n_finished == 1
    assert rep.avg_ttft == 1.0
    assert rep.throughput_qps == 0.5


def test_summarize_handles_missing_tpot_and_queue():
    # single-token decode: finish == first token, tpot defined but zero;
    # no admit_time recorded: queue_time is None and must be skipped
    one_tok = _req("a", submit=0.0, admit=None, first=1.0, finish=1.0,
                   tokens=(7,))
    assert one_tok.queue_time is None
    full = _req("b", submit=0.0, admit=0.25, first=0.5, finish=1.5,
                tokens=(1, 2, 3, 4))
    rep = summarize([one_tok, full], wall_time=2.0)
    assert rep.n_finished == 2
    assert rep.avg_queue == 0.25  # only b contributes
    assert rep.p99_queue == 0.25
    assert rep.avg_tpot > 0.0


def test_summarize_empty_iterable():
    rep = summarize([], wall_time=1.0)
    assert rep.n_finished == 0
    assert rep.avg_ttft == 0.0
    assert rep.p99_ttft == 0.0
    assert rep.throughput_qps == 0.0
    assert rep.ttft_pred_mae == 0.0


def test_summarize_calibration_fields():
    a = _req("a", submit=0.0, admit=0.1, first=1.0, finish=2.0, tokens=(1, 2))
    a.ttft_predicted = 1.2  # over-estimate by 0.2
    b = _req("b", submit=0.0, admit=0.1, first=1.0, finish=2.0, tokens=(1, 2))
    b.ttft_predicted = 0.9  # under-estimate by 0.1
    c = _req("c", submit=0.0, admit=0.1, first=1.0, finish=2.0, tokens=(1, 2))
    # c: no prediction sampled (tracing disabled) — excluded from calibration
    rep = summarize([a, b, c], wall_time=2.0)
    assert abs(rep.ttft_pred_mae - 0.15) < 1e-12
    assert abs(rep.ttft_pred_bias - 0.05) < 1e-12


def test_summarize_attribution_means():
    a = _req("a", submit=0.0, admit=0.1, first=1.0, finish=2.0, tokens=(1, 2))
    a.attribution = {"recompute": 0.2, "stall": 0.1, "compute": 0.7}
    b = _req("b", submit=0.0, admit=0.1, first=1.0, finish=2.0, tokens=(1, 2))
    rep = summarize([a, b], wall_time=2.0)
    assert abs(rep.avg_recompute - 0.1) < 1e-12
    assert abs(rep.avg_stall - 0.05) < 1e-12
