"""Mini dry-run in CI: lower+compile sharded steps on an 8-device host mesh.

A subprocess sets XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
main test process must keep its single device) and lowers a reduced arch per
family on a (4, 2) mesh — validating the sharding rules end-to-end without
the 512-way production sweep.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro import configs
from repro.launch.costs import cost_dict
from repro.distributed.sharding import (
    batch_specs, cache_specs, make_shardings, moment_specs, param_specs,
)
from repro.models import (
    build_model, make_decode_step, make_train_state, make_train_step,
)
from repro.models.model import TrainState

arch = sys_arch = %r
cfg = configs.reduced(configs.get(arch))
mesh = jax.make_mesh((4, 2), ("data", "model"))
model = build_model(cfg, dtype=jnp.float32)
out = {}
with mesh:
    # ---- train step
    ts = jax.eval_shape(lambda k: make_train_state(model, k, n_lora_slots=2),
                        jax.random.PRNGKey(0))
    spec = TrainState(
        params=param_specs(ts.params, mesh),
        lora=param_specs(ts.lora, mesh),
        opt=type(ts.opt)(m=moment_specs(ts.opt.m, mesh),
                         v=moment_specs(ts.opt.v, mesh),
                         step=jax.sharding.PartitionSpec()),
        step=jax.sharding.PartitionSpec(),
    )
    sh = make_shardings(spec, mesh)
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32),
        "adapter_ids": jax.ShapeDtypeStruct((8,), jnp.int32),
    }
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct((8, 4, cfg.d_model), jnp.float32)
    bsh = make_shardings(batch_specs(batch, mesh), mesh)
    step = make_train_step(model)
    compiled = jax.jit(step, in_shardings=(sh, bsh)).lower(ts, batch).compile()
    out["train_flops"] = cost_dict(compiled).get("flops", 0)
    # ---- decode step
    params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    lora = jax.eval_shape(lambda k: model.init_lora(k, 2), jax.random.PRNGKey(0))
    if cfg.is_encdec:
        cache = jax.eval_shape(lambda: model.init_cache(8, 32, src_len=8))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(8, 32))
    dbatch = {
        "tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32),
        "adapter_ids": jax.ShapeDtypeStruct((8,), jnp.int32),
    }
    psh = make_shardings(param_specs(params, mesh), mesh)
    lsh = make_shardings(param_specs(lora, mesh), mesh)
    csh = make_shardings(cache_specs(cache, mesh), mesh)
    dbsh = make_shardings(batch_specs(dbatch, mesh), mesh)
    dstep = make_decode_step(model)
    compiled = jax.jit(dstep, in_shardings=(psh, lsh, csh, dbsh)).lower(
        params, lora, cache, dbatch).compile()
    out["decode_ok"] = True
print("RESULT:" + json.dumps(out))
"""

FAMILIES = ["qwen3-0.6b", "deepseek-v2-lite-16b", "rwkv6-1.6b",
            "recurrentgemma-2b", "seamless-m4t-large-v2"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", FAMILIES)
def test_sharded_lower_compile_8dev(arch):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", "import sys\n" + SCRIPT % arch],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout[-2000:]
    res = json.loads(line[0][len("RESULT:"):])
    assert res.get("decode_ok") and res.get("train_flops", 0) > 0
