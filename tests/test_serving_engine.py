"""End-to-end engine tests: real JAX execution + FASTLIBRA cache management.

The key correctness property: generation with KV-cache reuse (FASTLIBRA hit
path) must produce the SAME tokens as a cold engine without any reuse.
"""

import itertools

import jax
import pytest

from repro import configs
from repro.serving import EngineConfig, Phase, Request, ServingEngine


def make_engine(variant="fastlibra", **kw):
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(
        hbm_bytes=kw.pop("hbm_bytes", 8 << 20),
        host_bytes=32 << 20,
        block_size=4,
        max_batch_slots=4,
        max_seq_len=96,
        variant=variant,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(3):
        eng.register_adapter(f"lora-{i}")
    return eng


_ids = itertools.count()


def req(adapter, prompt, n=4):
    return Request(f"r{next(_ids)}", adapter, tuple(prompt), max_new_tokens=n)


def test_single_request_completes():
    eng = make_engine()
    r = req("lora-0", range(10, 22), n=4)
    eng.submit(r)
    report = eng.run()
    assert report.n_finished == 1
    assert len(r.generated) == 4
    assert r.ttft is not None and r.ttft > 0
    eng.manager.check_invariants()


def test_prefix_reuse_preserves_tokens():
    """Turn 2 of a conversation must generate identical tokens whether the
    prefix KV comes from the cache (hit) or is recomputed (cold engine)."""
    prompt1 = tuple(range(10, 26))  # 16 tokens = 4 blocks

    eng = make_engine()
    r1 = req("lora-0", prompt1, n=8)
    eng.submit(r1)
    eng.run()
    follow = r1.full_tokens  # 24 tokens: the conversation so far
    # second turn on warm engine: prefix should hit
    r2 = req("lora-0", follow, n=4)
    eng.submit(r2)
    eng.run()
    assert r2.matched_tokens > 0, "prefix must match the cached conversation"
    assert r2.hbm_hit_tokens > 0

    cold = make_engine()
    r2c = req("lora-0", follow, n=4)
    cold.submit(r2c)
    cold.run()
    assert r2c.matched_tokens == 0
    assert tuple(r2.generated) == tuple(r2c.generated), (
        "KV reuse changed generation"
    )


def test_concurrent_multi_adapter_batch():
    eng = make_engine()
    rs = [req(f"lora-{i % 3}", range(30 + i, 42 + i), n=4) for i in range(6)]
    for r in rs:
        eng.submit(r)
    report = eng.run()
    assert report.n_finished == 6
    # batched multi-adapter decode must match per-request cold runs
    for r in rs[:2]:
        solo = make_engine()
        rr = req(r.adapter_id, r.prompt, n=4)
        solo.submit(rr)
        solo.run()
        assert tuple(rr.generated) == tuple(r.generated)


@pytest.mark.parametrize("variant", ["fastlibra", "vllm", "slora", "wom", "wos", "wol"])
def test_all_variants_serve(variant):
    eng = make_engine(variant=variant)
    rs = [req(f"lora-{i % 2}", range(50 + i, 60 + i), n=3) for i in range(4)]
    for r in rs:
        eng.submit(r)
    report = eng.run()
    assert report.n_finished == 4
    if variant == "slora":
        assert report.kv_hit_rate == 0.0  # S-LoRA never reuses history


def test_adapter_eviction_mid_decode_reloads():
    """Evicting a request's adapter mid-decode must reload it (charging the
    cold-start), NOT silently run the request through LoRA slot 0."""
    eng = make_engine()
    r = req("lora-2", range(10, 30), n=6)
    eng.submit(r)
    eng.step()
    eng.step()
    assert r.phase is Phase.DECODE
    eng.adapters.unload("lora-2")  # simulate a swapper eviction mid-flight
    assert eng.adapters.slot_of("lora-2") is None
    eng.run()
    assert r.phase is Phase.FINISHED
    assert eng.adapters.slot_of("lora-2") is not None, "adapter not reloaded"
    assert r.lora_coldstart > 0, "reload cold-start not charged"
    # generation must be identical to an uninterrupted run
    ref_eng = make_engine()
    ref = req("lora-2", range(10, 30), n=6)
    ref_eng.submit(ref)
    ref_eng.run()
    assert tuple(r.generated) == tuple(ref.generated)


def test_adapter_reload_evicts_idle_when_slots_full():
    """If every LoRA slot is occupied when a reload is needed, an idle
    resident adapter (not referenced by any active request) is evicted."""
    eng = make_engine()
    r = req("lora-2", range(10, 30), n=6)
    eng.submit(r)
    eng.step()
    eng.step()
    assert r.phase is Phase.DECODE
    eng.adapters.unload("lora-2")
    # fill every remaining slot with idle adapters (host-side registration
    # only, so the manager's swapper doesn't try its own swap-ins for them)
    i = 0
    while eng.adapters._free_slots:
        aid = f"idle-{i}"
        eng.adapters.register(aid, jax.random.PRNGKey(100 + i))
        eng.adapters.load(aid)
        i += 1
    assert not eng.adapters._free_slots
    eng.run()
    assert r.phase is Phase.FINISHED
    assert eng.adapters.slot_of("lora-2") is not None
    ref_eng = make_engine()
    ref = req("lora-2", range(10, 30), n=6)
    ref_eng.submit(ref)
    ref_eng.run()
    assert tuple(r.generated) == tuple(ref.generated)


def test_memory_pressure_eviction_and_correctness():
    eng = make_engine(hbm_bytes=3 << 20)  # tight HBM forces eviction
    rs = [req(f"lora-{i % 3}", range(70 + 7 * i, 86 + 7 * i), n=4) for i in range(8)]
    for r in rs:
        eng.submit(r)
    report = eng.run(max_steps=50_000)
    assert report.n_finished == 8
    assert report.invalid_kv_fraction == 0.0  # validity invariant held
    eng.manager.check_invariants()
