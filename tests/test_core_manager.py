"""Integration tests for the cache manager + swapper (FASTLIBRA §4–5)."""

import pytest

from repro.core import (
    CacheManager,
    HardwareModel,
    ManagerConfig,
    NodeKind,
    Residency,
    SwapKind,
    Tier,
    make_fastlibra,
)

KVB = 1024  # bytes per token (tiny, test-friendly)
BS = 4  # tokens per block
BLOCK_BYTES = KVB * BS


def mgr_pair(variant="fastlibra", hbm_blocks=64, host_blocks=256):
    return make_fastlibra(
        hbm_bytes=hbm_blocks * BLOCK_BYTES,
        host_bytes=host_blocks * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        variant=variant,
    )


def run_query(mgr, qid, lora, tokens, now, new_tokens=8):
    """Helper: full query lifecycle against the manager."""
    lk = mgr.lookup(lora, tokens, now)
    adm = mgr.admit(lk, now)
    assert not adm.queued
    blocks = mgr.allocate_running(qid, len(tokens) - lk.match.matched_tokens + new_tokens, now)
    assert blocks is not None
    full = tuple(tokens) + tuple(range(1000, 1000 + new_tokens))
    node = mgr.commit(qid, lk, full, now)
    mgr.unpin(adm.pinned)
    return lk, node


def test_register_and_swap_in_lora():
    mgr, _ = mgr_pair()
    op = mgr.register_lora("l1", size_bytes=2 * BLOCK_BYTES, now=0.0)
    assert op.kind is SwapKind.LOAD_NEW
    node = mgr.tree.lora_node("l1")
    assert node.tier is Residency.HOST and len(node.host_blocks) == 2
    lk = mgr.lookup("l1", (), now=1.0)
    assert not lk.lora_resident and lk.swap_in_nodes == [node]
    adm = mgr.admit(lk, now=1.0)
    assert node.tier is Residency.HBM
    assert [o.kind for o in adm.ops] == [SwapKind.SWAP_IN]
    assert adm.ops[0].nbytes == 2 * BLOCK_BYTES
    mgr.check_invariants()


def test_commit_inserts_block_aligned_suffix():
    mgr, _ = mgr_pair()
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    lk, node = run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=10)
    # 10 tokens -> 2 full blocks cached (8 tokens), partial tail freed
    assert node is not None and node.num_tokens == 8
    assert len(node.hbm_blocks) == 2
    mgr.check_invariants()


def test_prefix_reuse_across_queries():
    mgr, _ = mgr_pair()
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    _, node = run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=8)
    hist = node.path_tokens()
    lk2 = mgr.lookup("l1", hist, now=2.0)
    assert lk2.hbm_hit_tokens == 8
    assert lk2.match.matched_tokens == 8


def test_validity_invariant_maintained_under_pressure():
    mgr, _ = mgr_pair(hbm_blocks=8, host_blocks=64)
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    mgr.register_lora("l2", BLOCK_BYTES, now=0.0)
    now = 1.0
    for i in range(6):
        lora = "l1" if i % 2 == 0 else "l2"
        run_query(mgr, f"q{i}", lora, (), now=now, new_tokens=8)
        now += 1.0
        mgr.check_invariants()
    assert mgr.invalid_kv_fraction() == 0.0


def test_wom_variant_can_produce_invalid_kvs():
    mgr, _ = mgr_pair(variant="wom", hbm_blocks=6, host_blocks=64)
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    mgr.register_lora("l2", BLOCK_BYTES, now=0.0)
    run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=8)
    # force pressure so l1's LoRA can be evicted while its KVs stay
    run_query(mgr, "q1", "l2", (), now=2.0, new_tokens=8)
    # at most 6 blocks: the manager had to evict *something* independent of
    # the tree structure; dependency violations are possible in this variant.
    # We assert the invariant checker does NOT run for wom (config off) and
    # that the fraction is measurable (>= 0).
    assert mgr.invalid_kv_fraction() >= 0.0
    assert not mgr.config.maintain_dependencies


def test_slora_variant_discards_history():
    mgr, _ = mgr_pair(variant="slora")
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    lk, node = run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=8)
    assert node is None  # no KV retention
    lk2 = mgr.lookup("l1", tuple(range(1000, 1008)), now=2.0)
    assert lk2.hbm_hit_tokens == 0


def test_vllm_variant_static_partition():
    mgr, _ = mgr_pair(variant="vllm", hbm_blocks=10)
    assert mgr.lora_pool is not mgr.kv_pool
    assert mgr.lora_pool.num_hbm_blocks == 2  # 0.2 ratio
    assert mgr.kv_pool.num_hbm_blocks == 8
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=8)
    mgr.pool.check_invariants()


def test_eviction_prefers_low_eval():
    mgr, sw = mgr_pair(hbm_blocks=8, host_blocks=64)
    mgr.register_lora("hot", BLOCK_BYTES, now=0.0)
    mgr.register_lora("cold", BLOCK_BYTES, now=0.0)
    # hot LoRA visited many times, cold once, long ago
    for i in range(10):
        mgr.lookup("hot", (), now=float(i))
    mgr.lookup("cold", (), now=0.0)
    lk = mgr.lookup("hot", (), now=10.0)
    adm = mgr.admit(lk, now=10.0)
    lkc = mgr.lookup("cold", (), now=10.5)
    admc = mgr.admit(lkc, now=10.5)
    mgr.unpin(adm.pinned)
    mgr.unpin(admc.pinned)
    # fill HBM with running blocks to force eviction of one LoRA
    blocks = mgr.allocate_running("big", 7 * BS, now=11.0)
    assert blocks is not None
    hot, cold = mgr.tree.lora_node("hot"), mgr.tree.lora_node("cold")
    assert hot.tier is Residency.HBM
    assert cold.tier is Residency.HOST  # the colder one was chosen


def test_swapper_prefetch_on_idle():
    mgr, sw = mgr_pair(hbm_blocks=64, host_blocks=64)
    for i in range(5):
        mgr.register_lora(f"l{i}", BLOCK_BYTES, now=0.0)
        mgr.lookup(f"l{i}", (), now=0.1 * i)
    sw.observe_batch_size(4.0)
    ops = sw.tick(now=1.0)
    # idle HBM (0% < 70%): all 5 LoRAs prefetched host->HBM
    assert sum(1 for o in ops if o.kind is SwapKind.SWAP_IN) == 5
    assert mgr.tree.resident_lora_count() == 5


def test_swapper_evicts_on_busy():
    mgr, sw = mgr_pair(hbm_blocks=10, host_blocks=64)
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    run_query(mgr, "q0", "l1", (), now=0.5, new_tokens=8 * BS)
    # HBM now holds lora(1) + 8 kv blocks = 9/10 blocks = 90% -> not busy
    assert mgr.hbm_usage() == pytest.approx(0.9)
    mgr.allocate_running("q1", BS, now=0.6)  # 10/10 -> busy
    ops = sw.tick(now=0.7)
    assert any(o.kind is SwapKind.SWAP_OUT for o in ops)
    assert mgr.hbm_usage() <= sw.config.upper_threshold
    mgr.check_invariants()


def test_queueing_when_everything_pinned():
    mgr, _ = mgr_pair(hbm_blocks=4, host_blocks=16)
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    lk = mgr.lookup("l1", (), now=1.0)
    adm = mgr.admit(lk, now=1.0)
    blocks = mgr.allocate_running("q0", 3 * BS, now=1.0)
    assert blocks is not None  # 1 lora + 3 kv = all 4 blocks
    more = mgr.allocate_running("q1", BS, now=1.1)
    assert more is None  # nothing evictable: lora pinned, no cache nodes
    assert mgr.stats.queue_events == 1


def test_drop_when_host_full():
    mgr, sw = mgr_pair(hbm_blocks=8, host_blocks=1)
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=6 * BS)
    mgr.allocate_running("qX", BS, now=1.5)  # 8/8 busy
    ops = sw.tick(now=2.0)
    assert any(o.kind is SwapKind.DROP for o in ops)
    mgr.check_invariants()


def test_hit_rate_stats():
    mgr, _ = mgr_pair()
    mgr.register_lora("l1", BLOCK_BYTES, now=0.0)
    _, node = run_query(mgr, "q0", "l1", (), now=1.0, new_tokens=8)
    hist = node.path_tokens()
    mgr.lookup("l1", hist, now=2.0)
    s = mgr.stats
    assert s.kv_hit_rate() == pytest.approx(1.0)  # 8/8 history tokens hit
    assert 0.0 < s.lora_hit_rate() <= 1.0
