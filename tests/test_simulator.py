"""Discrete-event simulator tests: paper-claim orderings and mechanics."""

import pytest

from repro import configs
from repro.data import TraceConfig, generate_trace, trace_stats
from repro.sim import DeployedModel, ServingSimulator, SimConfig


def small_trace(scenario="agent", qps=1.0, n_loras=50, seed=5, duration=90.0):
    return generate_trace(TraceConfig(
        scenario=scenario, n_loras=n_loras, duration=duration,
        mean_qps=qps, seed=seed,
    ))


@pytest.fixture(scope="module")
def dep():
    return DeployedModel(configs.get("llama-7b"), cards=1)


def run(dep, trace, variant, **kw):
    return ServingSimulator(dep, trace, SimConfig(variant=variant, **kw)).run()


def test_all_queries_finish(dep):
    trace = small_trace()
    res = run(dep, trace, "fastlibra")
    assert len(res.finished) == len(trace)
    assert all(r.finish_time is not None for r in res.finished)
    res.manager.check_invariants()


def test_fastlibra_beats_slora_on_conversations(dep):
    """No history reuse (S-LoRA) must cost TTFT on multi-turn workloads."""
    trace = small_trace("agent")
    fl = run(dep, trace, "fastlibra")
    sl = run(dep, trace, "slora")
    assert fl.summary()["kv_hit_rate"] > 0.2
    assert sl.summary()["kv_hit_rate"] == 0.0
    assert fl.avg_ttft < sl.avg_ttft


def test_vllm_demand_eviction_costs_coldstart(dep):
    """Static-partition LRU pays synchronous swap cold-starts FASTLIBRA's
    proactive swapper avoids (needs enough load that the pool fills)."""
    trace = small_trace("chatbot", qps=2.0, duration=300.0, n_loras=100)
    fl = run(dep, trace, "fastlibra")
    vl = run(dep, trace, "vllm")
    assert vl.summary()["avg_hbm_usage"] > 0.5, "pool must be under pressure"
    assert vl.avg_kv_coldstart > fl.avg_kv_coldstart
    assert vl.avg_ttft > fl.avg_ttft


def test_invalid_kvs_only_in_dependency_blind_variants(dep):
    trace = small_trace("translation", qps=6.0, n_loras=200, duration=120.0)
    fl = run(dep, trace, "fastlibra")
    vl = run(dep, trace, "vllm")
    assert fl.summary()["avg_invalid_kv"] == 0.0
    assert vl.summary()["avg_invalid_kv"] >= 0.0  # can orphan KV subtrees
    fl.manager.tree.check_validity_invariant()


def test_timeline_monotonic_and_metrics_sane(dep):
    trace = small_trace()
    res = run(dep, trace, "fastlibra")
    ts = [t["t"] for t in res.timeline]
    assert ts == sorted(ts)
    for r in res.finished:
        assert r.ttft is not None and r.ttft >= 0
        assert r.finish_time >= r.first_token_time >= r.query.arrival
    assert 0 <= res.summary()["avg_hbm_usage"] <= 1


def test_straggler_mitigation_triggers():
    """With every transfer 10x slow, waits exceed the timeout and the sim
    falls back to recompute (hedged) instead of stalling."""
    dep = DeployedModel(configs.get("llama-7b"), cards=1)
    trace = small_trace("chatbot", qps=1.5, duration=90.0)
    res = run(dep, trace, "fastlibra", straggler_p=1.0, straggler_timeout=0.05)
    assert res.straggler_mitigations > 0
    assert len(res.finished) == len(trace)  # nobody stuck forever


def test_trace_generator_statistics():
    tr = small_trace("chatbot", qps=2.0, duration=120.0)
    st = trace_stats(tr)
    assert st["n_loras_used"] <= 50
    assert st["avg_output"] > 0 and st["avg_prompt"] > st["avg_history"]
    # multi-turn: histories must be non-empty for some queries
    assert any(len(q.history) > len(q.new_tokens) for q in tr)
    # deterministic for a fixed seed
    tr2 = small_trace("chatbot", qps=2.0, duration=120.0)
    assert [q.arrival for q in tr[:20]] == [q.arrival for q in tr2[:20]]
