"""Blockwise (memory-efficient) attention must match naive sdpa exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model, make_train_state
from repro.models.attention import causal_mask, sdpa, sdpa_blockwise, window_mask


@pytest.mark.parametrize("S,Hq,Hkv,D,qc", [
    (32, 4, 2, 16, 8),
    (48, 8, 1, 32, 16),   # MQA, S not a chunk multiple
    (17, 2, 2, 8, 8),     # ragged
])
def test_blockwise_matches_naive(S, Hq, Hkv, D, qc):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = sdpa(q, k, v, causal_mask(pos, pos))
    got = sdpa_blockwise(q, k, v, pos, pos, q_chunk=qc, k_chunk=qc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_windowed():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, D, W = 2, 40, 2, 16, 12
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    want = sdpa(q, k, v, window_mask(pos, pos, W))
    got = sdpa_blockwise(q, k, v, pos, pos, window=W, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_model_forward_parity_and_grads():
    """Full model forward + grads identical between naive and blockwise."""
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    naive = build_model(cfg, dtype=jnp.float32)
    block = build_model(cfg, dtype=jnp.float32, q_chunk=8)
    state = make_train_state(naive, jax.random.PRNGKey(0), n_lora_slots=2)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    ids = jnp.array([0, 1], jnp.int32)
    l1, _ = naive.forward(state.params, tokens, lora=state.lora, adapter_ids=ids)
    l2, _ = block.forward(state.params, tokens, lora=state.lora, adapter_ids=ids)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)

    def loss(m, p):
        lg, _ = m.forward(p, tokens, lora=state.lora, adapter_ids=ids)
        return jnp.mean(jnp.square(lg))

    g1 = jax.grad(lambda p: loss(naive, p))(state.params)
    g2 = jax.grad(lambda p: loss(block, p))(state.params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)
