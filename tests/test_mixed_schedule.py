"""Mixed prefill+decode step scheduler tests (serving/scheduler.py).

Four pinned properties:
  S1  planner math: decode-first packing, FCFS prefill fill, budget respected,
      progress guarantee; budget controller AIMD behavior and clamps
  S2  differential: mixed-mode serving is token-identical to
      ``prefill_mode="eager"`` end-to-end for ALL FOUR cache layouts
      (GQA / MLA / RWKV / RG-LRU), including a long prompt whose chunks
      interleave with another request's decode rows in the same batch
  S3  trace regression: a seeded multi-LoRA trace produces a sane report
      (finite positive latencies, rates in [0,1], bounded compiles) in BOTH
      schedule modes, so metric regressions fail loudly
  S4  the unified mixed-batch token count (not decode-slot occupancy) feeds
      the swapper/cost model; expected_lora_demand pinned by hand
"""

import itertools

import jax
import pytest

from repro import configs
from repro.core.cost_model import expected_lora_demand
from repro.serving import (
    EngineConfig,
    Phase,
    Request,
    ServingEngine,
    TokenBudgetController,
    plan_step,
)

# ------------------------------------------------------------ S1: planner


def test_plan_step_decode_first_then_even_split():
    plan = plan_step([0, 3], [(1, 100), (2, 10)], budget=40, chunk_ceiling=32)
    assert plan.decode_slots == (0, 3)
    # 40 - 2 decode tokens = 38 left: even share 19 each, row 2 only needs
    # 10, FCFS waterfill hands row 1 the 9-token leftover
    assert plan.prefill_chunks == {1: 28, 2: 10}
    assert plan.tokens == 2 + 28 + 10
    assert plan.tokens <= plan.budget


def test_plan_step_budget_respected_and_ceiling_applies():
    plan = plan_step([], [(0, 500), (1, 500)], budget=48, chunk_ceiling=64)
    assert plan.prefill_chunks == {0: 24, 1: 24}
    assert plan.tokens == 48
    # the per-row ceiling caps even a lone row with a huge budget
    plan = plan_step([], [(0, 500)], budget=4096, chunk_ceiling=64)
    assert plan.prefill_chunks == {0: 64}


def test_plan_step_progress_guarantee_under_decode_saturation():
    # decode alone exhausts the budget: the first prefill row still advances
    plan = plan_step(list(range(8)), [(9, 50), (10, 50)], budget=8,
                     chunk_ceiling=16)
    assert plan.prefill_chunks == {9: 1}
    # fewer leftover tokens than rows: 1 token each while the budget lasts
    plan = plan_step(list(range(8)), [(9, 50), (10, 50), (11, 50), (12, 50)],
                     budget=11, chunk_ceiling=16)
    assert plan.prefill_chunks == {9: 1, 10: 1, 11: 1}
    # rows with nothing left are skipped entirely
    plan = plan_step([], [(0, 0), (1, 5)], budget=16, chunk_ceiling=16)
    assert plan.prefill_chunks == {1: 5}


def test_budget_controller_aimd_and_clamps():
    ctl = TokenBudgetController(max_budget=256, target_step_ms=10.0,
                                min_budget=16)
    assert ctl.budget == 256
    for _ in range(30):  # sustained overshoot: shrink to the floor
        ctl.observe(50.0)
    assert ctl.budget == 16
    assert ctl.ema_ms > 10.0
    for _ in range(40):  # sustained headroom: grow back, clamped at max
        ctl.observe(1.0)
    assert ctl.budget == 256
    # static mode: target <= 0 never moves the budget
    ctl2 = TokenBudgetController(max_budget=64, target_step_ms=0.0)
    for _ in range(5):
        ctl2.observe(1000.0)
    assert ctl2.budget == 64
    assert ctl2.ema_ms > 0  # the EMA still tracks for reporting


def test_budget_controller_dead_band_holds():
    ctl = TokenBudgetController(max_budget=256, target_step_ms=10.0,
                                min_budget=16)
    ctl.observe(50.0)  # shrink once
    b = ctl.budget
    assert b < 256
    ctl.ema_ms = 9.0  # place the EMA inside [headroom*target, target]
    for _ in range(20):
        ctl.observe(9.0)
    assert ctl.budget == b


# ------------------------------------------------- S2: differential sweep

ARCHS = ["qwen3-0.6b", "deepseek-v2-lite-16b", "rwkv6-1.6b",
         "recurrentgemma-2b"]

_ids = itertools.count()


def _req(adapter, prompt, n=3):
    return Request(f"mx{next(_ids)}", adapter, tuple(prompt), max_new_tokens=n)


def _engine(arch, mode, schedule, budget=24, chunk=8):
    cfg = configs.reduced(configs.get(arch))
    ecfg = EngineConfig(
        hbm_bytes=8 << 20, host_bytes=32 << 20, block_size=4,
        max_batch_slots=4, max_seq_len=96, prefill_mode=mode,
        prefill_chunk=chunk, prefill_min_bucket=4,
        schedule_mode=schedule, step_token_budget=budget,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(3):
        eng.register_adapter(f"lora-{i}")
    return eng


def _workload():
    """Three short multi-LoRA prompts plus one 30-token prompt that must
    chunk (chunk=8 → 4 chunks) while the short rows decode."""
    reqs = [_req(f"lora-{i % 3}", range(30 + i, 40 + i + 2 * i)) for i in range(3)]
    reqs.append(_req("lora-1", range(100, 130)))
    return reqs


@pytest.mark.parametrize("arch", ARCHS)
def test_mixed_matches_eager_all_layouts(arch):
    outs = {}
    for mode, schedule in (("eager", "alternate"), ("bucketed", "mixed")):
        eng = _engine(arch, mode, schedule)
        reqs = _workload()
        for r in reqs:
            eng.submit(r)
        rep = eng.run()
        assert rep.n_finished == len(reqs)
        outs[schedule] = [tuple(r.generated) for r in reqs]
    assert outs["alternate"] == outs["mixed"], (
        f"{arch}: mixed scheduling changed generation")


def test_long_prompt_chunks_interleave_with_decode_rows():
    """The mixed batch must actually mix: while the long prompt is still
    PREFILLING, short requests keep generating *in the same step* — and the
    final tokens still match an eager run."""
    eng = _engine("qwen3-0.6b", "bucketed", "mixed", budget=12, chunk=8)
    short = _req("lora-0", range(10, 18), n=8)  # one 8-token chunk
    eng.submit(short)
    eng.step()  # short admitted + prefilled, starts decoding
    assert short.phase is Phase.DECODE
    long = _req("lora-1", range(100, 164), n=2)  # 64 tokens = 8 chunks
    eng.submit(long)
    mixed_steps = 0
    for _ in range(6):
        before = len(short.generated)
        eng.step()
        if long.phase is Phase.PREFILLING and len(short.generated) > before:
            mixed_steps += 1
    assert mixed_steps > 0, "decode starved while the long prompt prefilled"
    eng.run()
    assert long.phase is Phase.FINISHED and short.phase is Phase.FINISHED
    assert long.prefill_chunks >= 8

    ref = _engine("qwen3-0.6b", "eager", "alternate")
    rs = _req("lora-0", range(10, 18), n=8)
    ref.submit(rs)
    ref.step()
    rl = _req("lora-1", range(100, 164), n=2)
    ref.submit(rl)
    ref.run()
    assert tuple(short.generated) == tuple(rs.generated)
    assert tuple(long.generated) == tuple(rl.generated)


def test_dynamic_budget_engine_still_token_identical():
    """target_step_ms > 0 makes chunk sizes nondeterministic (wall-clock
    driven) — generation must be invariant to the chunking anyway."""
    eng = _engine("qwen3-0.6b", "bucketed", "mixed", budget=32)
    eng.budget_ctl.target_step_ms = 5.0
    reqs = _workload()
    for r in reqs:
        eng.submit(r)
    rep = eng.run()
    assert rep.n_finished == len(reqs)
    ref = _engine("qwen3-0.6b", "eager", "alternate")
    refs = _workload()
    for r in refs:
        ref.submit(r)
    ref.run()
    assert [tuple(r.generated) for r in reqs] == [
        tuple(r.generated) for r in refs]


# --------------------------------------------- S3: trace regression (both)


def _trace(n=10, seed=3):
    import numpy as np

    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        adapter = f"lora-{rng.randint(0, 3)}"
        plen = int(rng.choice([6, 9, 14, 21, 30]))
        prompt = tuple(int(t) for t in rng.randint(1, 500, size=plen))
        reqs.append(Request(f"tr{seed}-{i}", adapter, prompt,
                            max_new_tokens=4))
    return reqs


@pytest.mark.parametrize("schedule", ["mixed", "alternate"])
def test_trace_report_sanity(schedule):
    eng = _engine("qwen3-0.6b", "bucketed", schedule, budget=48, chunk=16)
    reqs = _trace()
    for r in reqs:
        eng.submit(r)
    rep = eng.run(max_steps=50_000)
    assert rep.n_finished == len(reqs)
    assert 0 < rep.avg_ttft < float("inf")
    assert 0 < rep.p99_ttft < float("inf")
    assert 0 < rep.avg_tpot < float("inf")
    assert 0 < rep.p99_tpot < float("inf")
    assert rep.p99_tpot >= rep.avg_tpot * 0.5  # p99 can't collapse below mean scale
    assert 0.0 <= rep.kv_hit_rate <= 1.0
    assert 0.0 <= rep.lora_hit_rate <= 1.0
    assert 0.0 <= rep.hbm_utilization <= 1.0
    # ≤ one lowered shape per (bucket × {prefill-only, mixed}) phase
    assert 0 < rep.prefill_compiles <= len(eng.prefill.buckets) * 2
    assert rep.avg_step_ms > 0
    assert rep.ema_step_ms > 0
    if schedule == "mixed":
        assert 0.0 < rep.budget_utilization <= 1.0
    eng.manager.check_invariants()


# ------------------------------------- S4: unified batch-size observation


def test_expected_lora_demand_hand_computed():
    # Eq. 3 with probs (.5, .25, .25) and BS=4:
    # (1-.5^4) + 2*(1-.75^4) = 0.9375 + 2*0.68359375
    val = expected_lora_demand([0.5, 0.25, 0.25], 4.0)
    assert val == pytest.approx(0.9375 + 2 * 0.68359375)
    # BS=0 → nothing demanded; huge BS → saturates to the adapter count
    assert expected_lora_demand([0.5, 0.25, 0.25], 0.0) == 0.0
    assert expected_lora_demand([0.5, 0.25, 0.25], 1e6) == pytest.approx(3.0)


@pytest.mark.parametrize("schedule", ["mixed", "alternate"])
def test_swapper_sees_token_load_not_slot_occupancy(schedule):
    """One 32-token prompt in one slot: the observed batch signal must be
    the chunk token count (≫ 1), not the single occupied decode slot."""
    eng = _engine("qwen3-0.6b", "bucketed", schedule, budget=64, chunk=32)
    eng.submit(_req("lora-0", range(200, 232), n=2))
    eng.step()  # admit + prefill the full 32-token suffix
    eng._observe_batch_size(eng._now())
    assert eng.swapper._recent_batch_size >= 30, (
        "swapper still sees slot occupancy, not mixed-batch tokens")
    assert eng.manager.scorer._recent_batch_size >= 30
