"""libra-check runtime sanitizer tests.

Three layers:
1. detection — deliberately corrupt a live manager in every way the
   sanitizer claims to catch, and assert the sweep raises a structured
   PoolInvariantError naming that invariant;
2. gating — REPRO_SANITIZE / ManagerConfig(sanitize=...) wire the sweep
   into every mutating public op (and the config flag beats the env);
3. a seeded-random lifecycle fuzz with sanitize=True + exact byte
   accounting that runs even where hypothesis is unavailable (the
   hypothesis fuzz in test_core_property.py covers the same ops deeper).

Plus the jit-cache compile-count regression for the bucketed prefill
engine (a 32-request mixed trace must stay within #buckets + #phases
distinct compiled programs).
"""

import random

import pytest

from repro.core import (
    ManagerConfig,
    CacheManager,
    NodeKind,
    PoolInvariantError,
    Residency,
    Tier,
    check_pool_invariants,
    jit_cache_size,
    make_fastlibra,
    sanitize_enabled,
)

KVB = 64
BS = 4
BLOCK_BYTES = KVB * BS


def _mgr(**kw):
    mgr, sw = make_fastlibra(
        hbm_bytes=kw.pop("hbm_blocks", 24) * BLOCK_BYTES,
        host_bytes=128 * BLOCK_BYTES,
        kv_bytes_per_token=KVB,
        block_size=BS,
        **kw,
    )
    for lid in "ab":
        mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
    return mgr, sw


def _one_query(mgr, lid="a", toks=tuple(range(12)), qid="q0", now=1.0):
    lk = mgr.lookup(lid, toks, now)
    adm = mgr.admit(lk, now)
    assert not adm.queued
    blocks = mgr.allocate_running(qid, len(toks), now)
    assert blocks is not None
    mgr.commit(qid, lk, toks, now)
    mgr.unpin(adm.pinned)
    return lk


def _expect(mgr, fragment):
    with pytest.raises(PoolInvariantError) as ei:
        check_pool_invariants(mgr)
    assert any(fragment in v for v in ei.value.violations), ei.value.violations
    return ei.value


# ------------------------------------------------------------- detection
def test_clean_manager_passes():
    mgr, _ = _mgr()
    _one_query(mgr)
    check_pool_invariants(mgr)  # must not raise


def test_detects_pool_partition_corruption():
    mgr, _ = _mgr()
    mgr.pool._allocated[Tier.HBM].add(10_000)
    err = _expect(mgr, "pool-partition")
    assert err.dump  # tree dump attached for forensics


def test_detects_leaked_block():
    mgr, _ = _mgr()
    _one_query(mgr)
    kv = next(mgr.tree.iter_nodes({NodeKind.KV}))
    kv.hbm_blocks.pop()  # node forgets a block it still has allocated
    _expect(mgr, "allocated-but-unowned")


def test_detects_block_aliasing():
    mgr, _ = _mgr()
    _one_query(mgr)
    kv = next(mgr.tree.iter_nodes({NodeKind.KV}))
    kv.hbm_blocks.append(kv.hbm_blocks[0])  # same block owned twice
    _expect(mgr, "block-aliasing")


def test_detects_validity_chain_break():
    mgr, _ = _mgr()
    _one_query(mgr)
    lora = mgr.tree.lora_node("a")
    lora.tier = Residency.HOST  # HBM KV child now hangs under a host parent
    _expect(mgr, "validity-chain")


def test_detects_tier_residency_mismatch():
    mgr, _ = _mgr()
    _one_query(mgr)
    kv = next(mgr.tree.iter_nodes({NodeKind.KV}))
    kv.tier = None  # dropped tier while still owning blocks
    _expect(mgr, "tier-residency")


def test_detects_byte_accounting_drift():
    mgr, _ = _mgr()
    _one_query(mgr)
    kv = next(mgr.tree.iter_nodes({NodeKind.KV}))
    # move a block out of the tree without releasing it in the pool: the
    # breakdown shrinks but the pool's used count does not
    kv.hbm_blocks.pop()
    kv.num_blocks -= 1
    _expect(mgr, "byte-accounting")


def test_detects_radix_key_mismatch():
    mgr, _ = _mgr()
    _one_query(mgr)
    lora = mgr.tree.lora_node("a")
    (key, child), = list(lora.children.items())
    del lora.children[key]
    lora.children[(99, 99, 99, 99)] = child  # key no longer the edge prefix
    _expect(mgr, "radix-structure")


def test_detects_negative_refcount():
    mgr, _ = _mgr()
    _one_query(mgr)
    next(mgr.tree.iter_nodes()).ref_count = -1
    _expect(mgr, "pin-bookkeeping")


def test_detects_running_block_mismatch():
    mgr, _ = _mgr()
    lk = mgr.lookup("a", tuple(range(8)), 1.0)
    adm = mgr.admit(lk, 1.0)
    mgr.allocate_running("open", 8, 1.0)
    mgr._running["open"].pop()  # lose a running block without accounting
    mgr.kv_pool.release(Tier.HBM, [])  # no-op, keeps pool consistent
    try:
        _expect(mgr, "pin-bookkeeping")
    finally:
        mgr._sanitize = False  # cleanup below would re-raise otherwise
        mgr.abort_running("open")
        mgr.unpin(adm.pinned)


def test_detects_partial_state_snapshot():
    mgr, _ = _mgr(state_bytes=2 * BLOCK_BYTES)
    # adapters start on HOST; admit "a" so the snapshot's ancestry is HBM
    adm = mgr.admit(mgr.lookup_state("a", (), 0.5), 0.5)
    node = mgr.commit_state("a", tuple(range(6)), now=1.0)
    assert node is not None and node.num_blocks == mgr.config.state_blocks
    stolen = node.hbm_blocks.pop()  # snapshots are indivisible
    try:
        _expect(mgr, "hollow-state")
    finally:
        node.hbm_blocks.append(stolen)


def test_detects_lora_registry_break():
    mgr, _ = _mgr()
    mgr.tree._lora_nodes["ghost"] = mgr.tree.lora_node("a")
    _expect(mgr, "lora-registry")


# ------------------------------------------- shared-prefix trunk (I-shared)
def _one_shared_query(mgr, lid="a", toks=tuple(range(12)), shared=8,
                      qid="s0", now=1.0):
    """Full lifecycle of a query whose first ``shared`` tokens are declared
    adapter-independent — commits a trunk span + an adapter fork."""
    lk = mgr.lookup(lid, toks, now, shared_prefix_len=shared)
    adm = mgr.admit(lk, now)
    assert not adm.queued
    assert mgr.allocate_running(qid, len(toks) + 4, now) is not None
    mgr.commit(qid, lk, toks + tuple(range(500, 504)), now)
    mgr.unpin(adm.pinned)
    return lk


def _trunk_and_fork(mgr):
    shared = [n for n in mgr.tree.shared_nodes()]
    assert shared, "no trunk node committed"
    trunk = shared[0]
    forks = [c for c in trunk.children.values() if c.lora_id is not None]
    assert forks, "no adapter fork under the trunk"
    return trunk, forks[0]


def test_shared_query_passes_and_splits_bytes():
    mgr, _ = _mgr(sanitize=True)
    _one_shared_query(mgr, lid="a", qid="s0")
    _one_shared_query(mgr, lid="b", qid="s1", now=2.0)
    trunk, fork = _trunk_and_fork(mgr)
    assert trunk.lora_id is None and fork.lora_id in ("a", "b")
    bd = mgr.hbm_breakdown()
    assert bd["shared_kv_bytes"] == len(trunk.hbm_blocks) * BLOCK_BYTES > 0
    check_pool_invariants(mgr)  # must not raise


def test_detects_trunk_with_sharing_disabled():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    mgr.config.share_prefix_kv = False  # trunk now structurally illegal
    _expect(mgr, "share_prefix_kv disabled")


def test_detects_non_kv_trunk_node():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    trunk, _ = _trunk_and_fork(mgr)
    trunk.kind = NodeKind.STATE  # lora_id=None must imply KV kind
    _expect(mgr, "trunk is KV-only")


def test_detects_state_fork_off_trunk():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    _, fork = _trunk_and_fork(mgr)
    fork.kind = NodeKind.STATE
    _expect(mgr, "forks off the shared trunk")


def test_detects_trunk_under_non_trunk_parent():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    trunk, _ = _trunk_and_fork(mgr)
    trunk.parent = mgr.tree.lora_node("a")
    _expect(mgr, "under non-trunk parent")


def test_detects_fork_with_detached_shared_parent():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    trunk, _ = _trunk_and_fork(mgr)
    trunk.parent = None  # trunk unhooked from the root: forks dangle
    _expect(mgr, "detached shared parent")


def test_detects_fork_key_mismatch():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    trunk, fork = _trunk_and_fork(mgr)
    key = mgr.tree._child_key(trunk, fork.lora_id, fork.tokens)
    del trunk.children[key]
    trunk.children[("ghost", (9, 9, 9, 9))] = fork
    _expect(mgr, "not reachable from its shared parent")


def test_detects_shared_byte_split_drift():
    mgr, _ = _mgr()
    _one_shared_query(mgr)
    orig = mgr.hbm_breakdown()

    def skewed():
        bd = dict(orig)
        bd["shared_kv_bytes"] += BLOCK_BYTES  # misclassified bytes
        bd["history_kv_bytes"] -= BLOCK_BYTES
        return bd

    mgr.hbm_breakdown = skewed
    _expect(mgr, "shared-prefix: hbm_breakdown shared_kv_bytes")


def test_detects_nan_score():
    mgr, _ = _mgr()
    _one_query(mgr)
    mgr.scorer.score = lambda node, now: float("nan")
    _expect(mgr, "scorer-consistency")


# ---------------------------------------------------------------- gating
def test_sanitize_config_flag_hooks_every_mutating_op():
    mgr, _ = _mgr(sanitize=True)
    _one_query(mgr)  # clean ops pass with the sweep armed
    mgr.pool._allocated[Tier.HBM].add(10_000)
    with pytest.raises(PoolInvariantError):
        mgr.lookup("a", (1, 2, 3, 4), 2.0)  # corruption caught at next op


def test_sanitize_env_gate(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert not sanitize_enabled()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_enabled()
    mgr = CacheManager(
        ManagerConfig(block_size=BS, kv_bytes_per_token=KVB),
        24 * BLOCK_BYTES, 128 * BLOCK_BYTES,
    )
    assert mgr._sanitize  # env picked up at construction
    # explicit config beats the env
    off = CacheManager(
        ManagerConfig(block_size=BS, kv_bytes_per_token=KVB, sanitize=False),
        24 * BLOCK_BYTES, 128 * BLOCK_BYTES,
    )
    assert not off._sanitize


def test_swapper_tick_runs_sanitize_sweep():
    mgr, sw = _mgr(sanitize=True, hbm_blocks=16)
    _one_query(mgr)
    mgr.pool._allocated[Tier.HBM].add(10_000)
    with pytest.raises(PoolInvariantError):
        sw.tick(5.0)


def test_sanitizer_is_pure_reads():
    """Enabling the sanitizer must not change pool behavior: the same op
    sequence yields an identical tree/pool state with it on and off."""

    def run(sanitize):
        mgr, sw = _mgr(sanitize=sanitize)
        for i in range(6):
            _one_query(mgr, lid="ab"[i % 2],
                       toks=tuple(range(i, i + 12)), qid=f"q{i}",
                       now=1.0 + i)
            sw.tick(2.0 + i)
        return (
            sorted((n.kind.value, n.tokens, n.tier and n.tier.value,
                    tuple(n.hbm_blocks), tuple(n.host_blocks))
                   for n in mgr.tree.iter_nodes()),
            mgr.pool.stats().hbm_used,
        )

    assert run(False) == run(True)


# ------------------------------------------------- seeded lifecycle fuzz
def test_seeded_fuzz_sanitized_exact_accounting():
    """Deterministic mini-fuzz of the full open-query lifecycle with the
    per-op sweep armed and byte accounting checked exactly after every op.
    Runs everywhere (no hypothesis dependency); the hypothesis fuzz in
    test_core_property.py explores the same op space adaptively."""
    rng = random.Random(0xF457)
    for trial in range(8):
        hbm_blocks = rng.randrange(10, 33)
        state = rng.random() < 0.5
        mgr, sw = make_fastlibra(
            hbm_bytes=hbm_blocks * BLOCK_BYTES,
            host_bytes=128 * BLOCK_BYTES,
            kv_bytes_per_token=KVB,
            block_size=BS,
            state_bytes=2 * BLOCK_BYTES if state else 0,
            sanitize=True,
        )
        for lid in "abc":
            mgr.register_lora(lid, BLOCK_BYTES, now=0.0)
        now, open_qs, qid = 1.0, [], 0
        for _ in range(120):
            now += 0.05
            op = rng.randrange(6)
            if op <= 1:  # begin
                lid = rng.choice("abc")
                toks = tuple(rng.randrange(8) for _ in range(rng.randrange(24)))
                if state and lid == "c":
                    lk = mgr.lookup_state(lid, toks, now)
                else:
                    # shared spans interleave with plain per-adapter queries
                    lk = mgr.lookup(lid, toks, now,
                                    shared_prefix_len=rng.choice(
                                        (0, 0, 4, 8, 12)))
                adm = mgr.admit(lk, now)
                if adm.queued:
                    mgr.drain_ops()
                else:
                    name = f"f{qid}"
                    qid += 1
                    need = len(toks) - lk.match.matched_tokens + rng.randrange(1, 12)
                    if mgr.allocate_running(name, need, now) is None:
                        mgr.abort_running(name)
                        mgr.unpin(adm.pinned)
                    else:
                        open_qs.append((name, lk, adm.pinned, toks, need))
            elif op == 2 and open_qs:  # grow
                name = open_qs[rng.randrange(len(open_qs))][0]
                mgr.allocate_running(name, rng.randrange(1, 8), now)
            elif op == 3 and open_qs:  # commit
                name, lk, pinned, toks, need = open_qs.pop(
                    rng.randrange(len(open_qs)))
                full = toks + tuple(range(1000, 1000 + need))
                mgr.commit(name, lk, full, now)
                mgr.unpin(pinned)
            elif op == 4 and open_qs:  # abort
                name, lk, pinned, *_ = open_qs.pop(rng.randrange(len(open_qs)))
                mgr.abort_running(name)
                mgr.unpin(pinned)
            elif op == 5 and state:  # snapshot boundary
                toks = tuple(rng.randrange(8) for _ in range(rng.randrange(1, 16)))
                mgr.commit_state("c", toks, now)
            else:  # swapper sweep
                sw.observe_batch_size(rng.uniform(0.0, 16.0))
                sw.tick(now)
                mgr.drain_ops()
            # exact accounting after EVERY op (the per-op sweep already ran
            # inside the mutating call; this pins breakdown == pool)
            bd = mgr.hbm_breakdown()
            used = (bd["lora_bytes"] + bd["history_kv_bytes"]
                    + bd["shared_kv_bytes"]
                    + bd["state_snapshot_bytes"] + bd["running_kv_bytes"])
            assert used == mgr.pool.stats().hbm_used * mgr.config.block_bytes
        for name, lk, pinned, toks, need in open_qs:
            mgr.abort_running(name)
            mgr.unpin(pinned)
        mgr.check_invariants()
        assert all(n.ref_count == 0 for n in mgr.tree.iter_nodes())


# -------------------------------------------------- compile-count probe
def test_jit_cache_size_duck_typing():
    assert jit_cache_size(lambda x: x) == 0  # plain callables count as 0

    jax = pytest.importorskip("jax")
    fn = jax.jit(lambda x: x + 1)
    assert jit_cache_size(fn) == 0
    fn(jax.numpy.ones((2,)))
    fn(jax.numpy.ones((2,)))  # same shape: no retrace
    assert jit_cache_size(fn) == 1
    fn(jax.numpy.ones((3,)))  # new shape: one more program
    assert jit_cache_size(fn) == 2


@pytest.mark.slow
def test_compile_count_bounded_on_mixed_trace():
    """A 32-request mixed trace (varied prompt lengths, interleaved decode)
    must compile at most #buckets prefill programs + 1 per fixed-shape
    phase entry point — per-value recompiles (e.g. a Python scalar sneaking
    into a jit signature) blow past this bound immediately."""
    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.serving import EngineConfig, Request, ServingEngine

    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(
        hbm_bytes=8 << 20, host_bytes=32 << 20, block_size=4,
        max_batch_slots=4, max_seq_len=96,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(7))
    for i in range(3):
        eng.register_adapter(f"lora-{i}")
    rng = random.Random(7)
    for i in range(32):
        plen = rng.randrange(6, 40)  # many distinct lengths, few buckets
        prompt = tuple(rng.randrange(10, 200) for _ in range(plen))
        eng.submit(Request(f"cc{i}", f"lora-{i % 3}", prompt,
                           max_new_tokens=rng.randrange(2, 5)))
    report = eng.run(max_steps=50_000)
    assert report.n_finished == 32
    counts = eng.compile_counts()
    n_buckets = len(eng.prefill.buckets)
    n_phases = 2  # prefill + decode entry points
    assert counts["prefill"] <= n_buckets, counts
    assert counts["decode"] <= 1, counts
    assert sum(counts.values()) <= n_buckets + n_phases, (
        counts, eng.prefill.buckets)
