"""libra-trace tests: tracer mechanics, engine instrumentation, TTFT
attribution exactness, cache-decision audit coverage, Chrome/Perfetto
export validity, the disabled-tracer overhead gate, sim parity, and the
report CLI.

The engine acceptance run serves a 32-request multi-LoRA trace with
tracing armed on a deliberately tight HBM pool (256 KB) so demand
evictions actually happen — every one must land in the audit log with the
cost-model score it was chosen by, and every finished request must carry
an additive TTFT attribution that reconciles against its measured TTFT
within 1% (by construction it reconciles exactly).
"""

import json
import random

import pytest

from repro.obs import (
    ATTRIB_CATEGORIES,
    EV_CACHE_DROP,
    EV_CACHE_EVICT,
    EV_CACHE_SWAP_OUT,
    EV_CALIBRATION,
    EV_FINISH,
    EV_SUBMIT,
    EV_TTFT_ATTRIBUTION,
    NULL_TRACER,
    TRACK_CACHE,
    TRACK_ENGINE,
    NullTracer,
    Tracer,
    trace_env_enabled,
)

EVICT_EVENTS = (EV_CACHE_EVICT, EV_CACHE_SWAP_OUT, EV_CACHE_DROP)


# ------------------------------------------------------------- unit: tracer
def test_ring_buffer_caps_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(TRACK_ENGINE, "ev", float(i))
    assert len(tr.events) == 4
    assert tr.dropped_events == 6
    # the ring keeps the NEWEST events
    assert [e.ts for e in tr.events] == [6.0, 7.0, 8.0, 9.0]


def test_span_duration_and_counters():
    tr = Tracer()
    tr.span(TRACK_ENGINE, "work", 1.0, 1.5, rid="r1")
    tr.span(TRACK_ENGINE, "clamped", 2.0, 1.0)  # t1 < t0 clamps to 0
    tr.counter("queue_depth", 3.0, waiting=2.0)
    tr.count("cache.evict")
    tr.count("cache.evict", 2)
    tr.gauge("hbm", 0.7)
    evs = list(tr.events)
    assert evs[0].dur == 0.5 and evs[0].args == {"rid": "r1"}
    assert evs[1].dur == 0.0
    assert tr.counts["cache.evict"] == 3
    assert tr.gauges["hbm"] == 0.7


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert isinstance(NULL_TRACER, NullTracer)
    NULL_TRACER.span(TRACK_ENGINE, "x", 0.0, 1.0)
    NULL_TRACER.instant(TRACK_ENGINE, "x", 0.0)
    NULL_TRACER.audit("cache.evict", 0.0, node_id=1)
    NULL_TRACER.count("x")
    NULL_TRACER.gauge("x", 1.0)
    assert len(NULL_TRACER.events) == 0
    assert NULL_TRACER.counts == {}
    assert NULL_TRACER.gauges == {}


def test_export_chrome_is_valid_trace(tmp_path):
    tr = Tracer()
    tr.span(TRACK_ENGINE, "span", 1.0, 1.25, rid="r")
    tr.instant(TRACK_CACHE, "cache.evict", 2.0, node_id=3)
    tr.counter("queue_depth", 3.0, waiting=1.0)
    doc = tr.export_chrome()
    assert isinstance(doc["traceEvents"], list)
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "i", "C"} <= phases
    span = next(e for e in doc["traceEvents"] if e["ph"] == "X")
    assert span["ts"] == 1.0e6 and span["dur"] == 0.25e6  # µs
    inst = next(e for e in doc["traceEvents"] if e["ph"] == "i")
    assert inst["s"] == "t"
    # one tid per track, named via metadata
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} >= {TRACK_ENGINE, TRACK_CACHE}
    # dump() writes the same JSON-serializable document
    path = tmp_path / "t.json"
    tr.dump(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_env_arming(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert trace_env_enabled() is False
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert trace_env_enabled() is True
    from repro.sim import SimConfig

    assert SimConfig().trace is True
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert SimConfig().trace is False


# ------------------------------------------------- engine acceptance run
N_REQUESTS = 32
N_ADAPTERS = 4


def _mk_engine(trace: bool, hbm_bytes: int = 256 << 10, key: int = 7):
    jax = pytest.importorskip("jax")
    from repro import configs
    from repro.serving import EngineConfig, ServingEngine

    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(
        hbm_bytes=hbm_bytes, host_bytes=32 << 20, block_size=4,
        max_batch_slots=4, max_seq_len=96, trace=trace,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(key))
    for i in range(N_ADAPTERS):
        eng.register_adapter(f"lora-{i}")
    return eng


def _mk_trace(n=N_REQUESTS, seed=7):
    from repro.serving import Request

    rng = random.Random(seed)
    reqs = []
    for i in range(n):
        plen = rng.randrange(6, 40)
        prompt = tuple(rng.randrange(10, 200) for _ in range(plen))
        reqs.append(Request(f"t{i}", f"lora-{i % N_ADAPTERS}", prompt,
                            max_new_tokens=rng.randrange(2, 5)))
    return reqs


@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    """One traced 32-request run on a tight pool: (engine, report, doc)."""
    eng = _mk_engine(trace=True)
    for r in _mk_trace():
        eng.submit(r)
    report = eng.run(max_steps=50_000)
    path = tmp_path_factory.mktemp("trace") / "engine_trace.json"
    eng.export_trace(str(path))
    doc = json.loads(path.read_text())
    return eng, report, doc, str(path)


def test_traced_run_finishes_and_attribution_reconciles(traced_run):
    eng, report, _, _ = traced_run
    assert report.n_finished == N_REQUESTS
    for r in eng.finished:
        att = r.ttft_attribution()
        assert att is not None, r.request_id
        assert set(att) <= set(ATTRIB_CATEGORIES), att
        resid = abs(sum(att.values()) - r.ttft)
        assert resid <= 0.01 * r.ttft + 1e-9, (
            f"{r.request_id}: attribution {sum(att.values()):.6f}s vs "
            f"ttft {r.ttft:.6f}s")


def test_every_eviction_in_audit_log_with_score(traced_run):
    eng, _, _, _ = traced_run
    evs = [e for e in eng.tracer.events if e.name in EVICT_EVENTS]
    assert evs, "tight pool produced no evictions — shrink hbm_bytes"
    for e in evs:
        assert e.args is not None
        assert "node_id" in e.args and "bytes" in e.args and "kind" in e.args
        assert e.args.get("score") is not None, e
    # decision events carry the competing candidates they beat
    decided = [e for e in evs if e.name == EV_CACHE_EVICT]
    assert decided and all("beat" in e.args for e in decided)
    # every audited eviction also bumped the counter registry
    n_out = eng.tracer.counts.get(EV_CACHE_SWAP_OUT, 0)
    n_drop = eng.tracer.counts.get(EV_CACHE_DROP, 0)
    assert n_out + n_drop == sum(
        1 for e in evs if e.name in (EV_CACHE_SWAP_OUT, EV_CACHE_DROP))


def test_exported_json_is_chrome_loadable(traced_run):
    _, _, doc, _ = traced_run
    assert isinstance(doc, dict) and isinstance(doc["traceEvents"], list)
    assert doc["traceEvents"], "empty trace"
    for e in doc["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] != "M":  # thread-name metadata has no timestamp
            assert isinstance(e["ts"], (int, float))
    assert doc["otherData"]["droppedEvents"] == 0


def test_calibration_series_covers_every_finished_request(traced_run):
    eng, report, _, _ = traced_run
    assert all(r.ttft_predicted is not None for r in eng.finished)
    n_cal = sum(1 for e in eng.tracer.events if e.name == EV_CALIBRATION)
    assert n_cal == report.n_finished
    n_att = sum(1 for e in eng.tracer.events
                if e.name == EV_TTFT_ATTRIBUTION)
    assert n_att == report.n_finished
    # calibration aggregates surface in the report
    assert report.ttft_pred_mae > 0.0


def test_report_cli_renders_engine_trace(traced_run, capsys):
    _, _, _, path = traced_run
    from repro.obs.report import main as report_main

    assert report_main([path, "--top", "5"]) == 0
    out = capsys.readouterr().out
    for section in ("span histograms", "cache audit", "TTFT attribution",
                    "estimate_ttft calibration"):
        assert section in out
    assert EV_CACHE_SWAP_OUT in out


# ------------------------------------------------------- overhead gate
def test_disabled_tracer_overhead_gate():
    """The blocking CI gate: with tracing off, the engine uses the shared
    NULL_TRACER (no buffers, no events), compiles exactly the same
    programs, and produces token-identical output to a traced engine on
    the same trace — the tracer must observe, never steer."""
    eng_off = _mk_engine(trace=False, hbm_bytes=8 << 20)
    eng_on = _mk_engine(trace=True, hbm_bytes=8 << 20)
    assert eng_off.tracer is NULL_TRACER
    for eng in (eng_off, eng_on):
        for r in _mk_trace(n=12, seed=3):
            eng.submit(r)
        rep = eng.run(max_steps=50_000)
        assert rep.n_finished == 12
    assert len(eng_off.tracer.events) == 0
    assert eng_off.tracer.counts == {}
    assert eng_off.compile_counts() == eng_on.compile_counts()
    toks_off = {r.request_id: r.output_tokens for r in eng_off.finished}
    toks_on = {r.request_id: r.output_tokens for r in eng_on.finished}
    assert toks_off == toks_on
    # disabled requests still do the cheap host-float accounting, but no
    # prediction is sampled (that needs the armed tracer)
    assert all(r.ttft_predicted is None for r in eng_off.finished)


# ------------------------------------------------------------ sim parity
def test_sim_emits_shared_vocabulary_and_exact_attribution(tmp_path):
    from repro import configs
    from repro.data import TraceConfig, generate_trace
    from repro.sim import DeployedModel, ServingSimulator, SimConfig

    trace = generate_trace(TraceConfig(
        scenario="agent", n_loras=10, duration=30.0, mean_qps=1.5, seed=3))
    sim = ServingSimulator(
        DeployedModel(configs.get("llama-7b"), cards=1), trace,
        SimConfig(variant="fastlibra", trace=True, schedule_mode="mixed"))
    res = sim.run()
    assert len(res.finished) == len(trace)
    names = {e.name for e in sim.tracer.events}
    # same vocabulary the engine emits (constants shared via repro.obs)
    assert {EV_SUBMIT, EV_FINISH, EV_TTFT_ATTRIBUTION, EV_CALIBRATION,
            "req.admit", "req.queue", "engine.step",
            "prefill.chunk", "cache.admit"} <= names
    for r in res.finished:
        if r.ttft is None:
            continue
        resid = abs(sum(r.attribution.values()) - r.ttft)
        assert resid <= 0.01 * r.ttft + 1e-9, (r.rid, r.attribution, r.ttft)
    path = tmp_path / "sim_trace.json"
    sim.export_trace(str(path))
    doc = json.loads(path.read_text())
    assert doc["traceEvents"]


def test_sim_untraced_uses_null_tracer():
    from repro import configs
    from repro.data import TraceConfig, generate_trace
    from repro.sim import DeployedModel, ServingSimulator, SimConfig

    trace = generate_trace(TraceConfig(
        scenario="chatbot", n_loras=5, duration=10.0, mean_qps=1.0, seed=1))
    sim = ServingSimulator(
        DeployedModel(configs.get("llama-7b"), cards=1), trace,
        SimConfig(variant="fastlibra", trace=False))
    sim.run()
    assert sim.tracer is NULL_TRACER
    assert len(sim.tracer.events) == 0
