"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_prefill, paged_attention, ref, sgmv

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype):
    if dtype == jnp.int32:
        return jax.random.randint(key, shape, 0, 100)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ------------------------------------------------------------------- sgmv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,d_in,r,d_out,N",
    [
        (2, 16, 64, 8, 64, 3),
        (4, 128, 256, 32, 128, 5),
        (1, 7, 96, 16, 320, 2),   # ragged S, non-multiple d_out
        (8, 1, 128, 64, 256, 8),  # decode: S=1
    ],
)
def test_sgmv_matches_ref(B, S, d_in, r, d_out, N, dtype):
    ks = jax.random.split(KEY, 4)
    x = rand(ks[0], (B, S, d_in), dtype)
    a = rand(ks[1], (N, d_in, r), dtype) * 0.1
    b = rand(ks[2], (N, r, d_out), dtype) * 0.1
    ids = jax.random.randint(ks[3], (B,), 0, N)
    got = sgmv(x, a, b, ids, scale=0.5, block_s=32, block_o=64, interpret=True)
    want = ref.sgmv_ref(x, a, b, ids, scale=0.5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


def test_sgmv_negative_id_masks_base_rows():
    """id = -1 marks a base-model row (shared-prefix span): its delta must
    be exactly zero in the kernel, the reference, AND models.common — the
    cross-adapter KV-sharing contract."""
    from repro.models.common import lora_delta

    ks = jax.random.split(KEY, 3)
    x = rand(ks[0], (4, 16, 64), jnp.float32)
    a = rand(ks[1], (3, 64, 8), jnp.float32) * 0.1
    b = rand(ks[2], (3, 8, 64), jnp.float32) * 0.1
    ids = jnp.asarray([1, -1, 2, -1], jnp.int32)
    got = sgmv(x, a, b, ids, scale=0.5, interpret=True)
    want = ref.sgmv_ref(x, a, b, ids, scale=0.5)
    jnp_ref = lora_delta(x, a, b, ids, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp_ref),
                               rtol=2e-5, atol=2e-4)
    assert np.all(np.asarray(got)[1] == 0) and np.all(np.asarray(got)[3] == 0)
    live = sgmv(x, a, b, jnp.asarray([1, 1, 2, 2], jnp.int32),
                scale=0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(got)[0], np.asarray(live)[0])
    np.testing.assert_array_equal(np.asarray(got)[2], np.asarray(live)[2])


# -------------------------------------------------------------- paged attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,D,page,pages_per_seq,P",
    [
        (2, 4, 2, 32, 8, 3, 16),
        (3, 8, 1, 64, 16, 4, 32),  # MQA
        (1, 4, 4, 128, 8, 2, 8),   # MHA
    ],
)
def test_paged_attention_matches_ref(B, H, Hkv, D, page, pages_per_seq, P, dtype):
    ks = jax.random.split(KEY, 5)
    q = rand(ks[0], (B, H, D), dtype)
    kp = rand(ks[1], (P, page, Hkv, D), dtype)
    vp = rand(ks[2], (P, page, Hkv, D), dtype)
    # distinct pages per sequence
    perm = jax.random.permutation(ks[3], P)[: B * pages_per_seq]
    tables = perm.reshape(B, pages_per_seq).astype(jnp.int32)
    maxlen = page * pages_per_seq
    lengths = jax.random.randint(ks[4], (B,), 1, maxlen + 1)
    got = paged_attention(q, kp, vp, tables, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


# ------------------------------------------------------------ flash prefill
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,D,bq,bk",
    [
        (2, 4, 4, 64, 32, 16, 16),
        (1, 8, 2, 128, 64, 32, 64),  # GQA, uneven blocks
        (2, 2, 1, 96, 32, 32, 32),   # MQA, S not multiple of block
    ],
)
def test_flash_prefill_matches_ref(B, H, Hkv, S, D, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, S, D), dtype)
    k = rand(ks[1], (B, Hkv, S, D), dtype)
    v = rand(ks[2], (B, Hkv, S, D), dtype)
    got = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


# ------------------------------------------------- property: sgmv linearity
def test_sgmv_zero_b_is_zero():
    x = jnp.ones((2, 8, 32), jnp.float32)
    a = jnp.ones((2, 32, 4), jnp.float32)
    b = jnp.zeros((2, 4, 16), jnp.float32)
    ids = jnp.zeros((2,), jnp.int32)
    out = sgmv(x, a, b, ids, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def test_sgmv_adapter_selectivity():
    """Each sequence must use exactly its own adapter."""
    ks = jax.random.split(KEY, 3)
    x = rand(ks[0], (3, 4, 16), jnp.float32)
    a = rand(ks[1], (3, 16, 4), jnp.float32)
    b = rand(ks[2], (3, 4, 8), jnp.float32)
    ids = jnp.array([2, 0, 1], jnp.int32)
    out = sgmv(x, a, b, ids, interpret=True)
    for i, aid in enumerate([2, 0, 1]):
        want = (x[i] @ a[aid]) @ b[aid]
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want), rtol=1e-5)
