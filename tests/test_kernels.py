"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    flash_prefill,
    flash_prefill_ragged,
    fused_sgmv,
    paged_attention,
    ragged_extend,
    ref,
    sgmv,
)

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype):
    if dtype == jnp.int32:
        return jax.random.randint(key, shape, 0, 100)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ------------------------------------------------------------------- sgmv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,d_in,r,d_out,N",
    [
        (2, 16, 64, 8, 64, 3),
        (4, 128, 256, 32, 128, 5),
        (1, 7, 96, 16, 320, 2),   # ragged S, non-multiple d_out
        (8, 1, 128, 64, 256, 8),  # decode: S=1
    ],
)
def test_sgmv_matches_ref(B, S, d_in, r, d_out, N, dtype):
    ks = jax.random.split(KEY, 4)
    x = rand(ks[0], (B, S, d_in), dtype)
    a = rand(ks[1], (N, d_in, r), dtype) * 0.1
    b = rand(ks[2], (N, r, d_out), dtype) * 0.1
    ids = jax.random.randint(ks[3], (B,), 0, N)
    got = sgmv(x, a, b, ids, scale=0.5, block_s=32, block_o=64, interpret=True)
    want = ref.sgmv_ref(x, a, b, ids, scale=0.5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


def test_sgmv_negative_id_masks_base_rows():
    """id = -1 marks a base-model row (shared-prefix span): its delta must
    be exactly zero in the kernel, the reference, AND models.common — the
    cross-adapter KV-sharing contract."""
    from repro.models.common import lora_delta

    ks = jax.random.split(KEY, 3)
    x = rand(ks[0], (4, 16, 64), jnp.float32)
    a = rand(ks[1], (3, 64, 8), jnp.float32) * 0.1
    b = rand(ks[2], (3, 8, 64), jnp.float32) * 0.1
    ids = jnp.asarray([1, -1, 2, -1], jnp.int32)
    got = sgmv(x, a, b, ids, scale=0.5, interpret=True)
    want = ref.sgmv_ref(x, a, b, ids, scale=0.5)
    jnp_ref = lora_delta(x, a, b, ids, scale=0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp_ref),
                               rtol=2e-5, atol=2e-4)
    assert np.all(np.asarray(got)[1] == 0) and np.all(np.asarray(got)[3] == 0)
    live = sgmv(x, a, b, jnp.asarray([1, 1, 2, 2], jnp.int32),
                scale=0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(got)[0], np.asarray(live)[0])
    np.testing.assert_array_equal(np.asarray(got)[2], np.asarray(live)[2])


# -------------------------------------------------------------- paged attn
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,D,page,pages_per_seq,P",
    [
        (2, 4, 2, 32, 8, 3, 16),
        (3, 8, 1, 64, 16, 4, 32),  # MQA
        (1, 4, 4, 128, 8, 2, 8),   # MHA
    ],
)
def test_paged_attention_matches_ref(B, H, Hkv, D, page, pages_per_seq, P, dtype):
    ks = jax.random.split(KEY, 5)
    q = rand(ks[0], (B, H, D), dtype)
    kp = rand(ks[1], (P, page, Hkv, D), dtype)
    vp = rand(ks[2], (P, page, Hkv, D), dtype)
    # distinct pages per sequence
    perm = jax.random.permutation(ks[3], P)[: B * pages_per_seq]
    tables = perm.reshape(B, pages_per_seq).astype(jnp.int32)
    maxlen = page * pages_per_seq
    lengths = jax.random.randint(ks[4], (B,), 1, maxlen + 1)
    got = paged_attention(q, kp, vp, tables, lengths, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


# ------------------------------------------------------------ flash prefill
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Hkv,S,D,bq,bk",
    [
        (2, 4, 4, 64, 32, 16, 16),
        (1, 8, 2, 128, 64, 32, 64),  # GQA, uneven blocks
        (2, 2, 1, 96, 32, 32, 32),   # MQA, S not multiple of block
    ],
)
def test_flash_prefill_matches_ref(B, H, Hkv, S, D, bq, bk, dtype):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, S, D), dtype)
    k = rand(ks[1], (B, Hkv, S, D), dtype)
    v = rand(ks[2], (B, Hkv, S, D), dtype)
    got = flash_prefill(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


# ------------------------------------------------- property: sgmv linearity
def test_sgmv_zero_b_is_zero():
    x = jnp.ones((2, 8, 32), jnp.float32)
    a = jnp.ones((2, 32, 4), jnp.float32)
    b = jnp.zeros((2, 4, 16), jnp.float32)
    ids = jnp.zeros((2,), jnp.int32)
    out = sgmv(x, a, b, ids, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


def test_sgmv_adapter_selectivity():
    """Each sequence must use exactly its own adapter."""
    ks = jax.random.split(KEY, 3)
    x = rand(ks[0], (3, 4, 16), jnp.float32)
    a = rand(ks[1], (3, 16, 4), jnp.float32)
    b = rand(ks[2], (3, 4, 8), jnp.float32)
    ids = jnp.array([2, 0, 1], jnp.int32)
    out = sgmv(x, a, b, ids, interpret=True)
    for i, aid in enumerate([2, 0, 1]):
        want = (x[i] @ a[aid]) @ b[aid]
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want), rtol=1e-5)


# ---------------------------------------------------- paged attn edge cases
def test_paged_attention_zero_length_row_is_zero():
    """lens[b] == 0 must yield exactly zero output. Historically the kernel
    softmaxed an all-masked row (exp(-inf - -inf) == 1) and emitted mean(V);
    the fix zeroes masked probabilities before accumulating."""
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (3, 4, 32), jnp.float32)
    kp = rand(ks[1], (12, 8, 2, 32), jnp.float32)
    vp = rand(ks[2], (12, 8, 2, 32), jnp.float32) + 1.0  # nonzero mean(V)
    tables = jnp.arange(12, dtype=jnp.int32).reshape(3, 4)
    lens = jnp.asarray([17, 0, 32], jnp.int32)
    got = np.asarray(paged_attention(q, kp, vp, tables, lens, interpret=True))
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, tables, lens))
    assert np.all(got[1] == 0.0), "len-0 row must be zero, not mean(V)"
    assert np.all(want[1] == 0.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_paged_attention_length_not_page_multiple():
    """Partial last pages: the trimmed index map must still fetch the page
    holding the final tokens, and masking must cut exactly at lens[b]."""
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (2, 4, 32), jnp.float32)
    kp = rand(ks[1], (8, 16, 2, 32), jnp.float32)
    vp = rand(ks[2], (8, 16, 2, 32), jnp.float32)
    tables = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    lens = jnp.asarray([33, 7], jnp.int32)  # 3 pages part-full, 1 page part-full
    got = paged_attention(q, kp, vp, tables, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


# ------------------------------------------------------------- fused sgmv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,d_in,r,d_out,N,bs,bo",
    [
        (2, 16, 64, 8, 64, 3, 16, 32),
        (1, 7, 96, 16, 320, 2, 32, 64),   # S, d_out non-multiples of blocks
        (8, 1, 128, 64, 256, 8, 128, 128),  # decode: S=1 < block_s
        (3, 100, 64, 4, 72, 2, 32, 32),   # both dims ragged
    ],
)
def test_fused_sgmv_matches_ref(B, S, d_in, r, d_out, N, bs, bo, dtype):
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (B, S, d_in), dtype)
    w = rand(ks[1], (d_in, d_out), dtype) * 0.1
    a = rand(ks[2], (N, d_in, r), dtype) * 0.1
    b = rand(ks[3], (N, r, d_out), dtype) * 0.1
    ids = jax.random.randint(ks[4], (B,), -1, N)  # include base-model rows
    got = fused_sgmv(x, w, a, b, ids, scale=0.5, block_s=bs, block_o=bo,
                     interpret=True)
    want = ref.fused_sgmv_ref(x, w, a, b, ids, scale=0.5)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


def test_fused_sgmv_all_negative_ids_is_base_matmul():
    """A batch of only base-model rows (every id negative) must reduce to
    the plain x @ W — the delta term fully masked, no NaN from the clamped
    slot-0 gather."""
    ks = jax.random.split(KEY, 4)
    x = rand(ks[0], (4, 9, 48), jnp.float32)
    w = rand(ks[1], (48, 80), jnp.float32)
    a = rand(ks[2], (2, 48, 8), jnp.float32)
    b = rand(ks[3], (2, 8, 80), jnp.float32)
    ids = jnp.asarray([-1, -1, -1, -1], jnp.int32)
    got = fused_sgmv(x, w, a, b, ids, scale=2.0, block_s=16, block_o=32,
                     interpret=True)
    want = jnp.einsum("bsd,do->bso", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


def test_sgmv_all_negative_ids_is_zero():
    ks = jax.random.split(KEY, 3)
    x = rand(ks[0], (3, 5, 32), jnp.float32)
    a = rand(ks[1], (2, 32, 4), jnp.float32)
    b = rand(ks[2], (2, 4, 16), jnp.float32)
    ids = jnp.asarray([-1, -2, -1], jnp.int32)
    out = sgmv(x, a, b, ids, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0


# ------------------------------------------------------ flash prefill edges
def test_flash_prefill_s_not_block_multiple():
    """S=100 with 32-blocks: the padded tail rows must come back zero-safe
    and the live rows must match the oracle exactly."""
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 4, 100, 32), jnp.float32)
    k = rand(ks[1], (1, 2, 100, 32), jnp.float32)
    v = rand(ks[2], (1, 2, 100, 32), jnp.float32)
    got = flash_prefill(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


# ----------------------------------------------------- flash prefill ragged
@pytest.mark.parametrize(
    "lens",
    [
        [64, 64],          # full — must equal the plain kernel
        [64, 33],          # ragged, non-multiple of block
        [17, 0],           # tiny + empty row
    ],
)
def test_flash_prefill_ragged_matches_ref(lens):
    B, H, Hkv, S, D = len(lens), 4, 2, 64, 32
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, S, D), jnp.float32)
    k = rand(ks[1], (B, Hkv, S, D), jnp.float32)
    v = rand(ks[2], (B, Hkv, S, D), jnp.float32)
    tl = jnp.asarray(lens, jnp.int32)
    got = flash_prefill_ragged(q, k, v, tl, block_q=16, block_k=16,
                               interpret=True)
    want = ref.flash_prefill_ragged_ref(q, k, v, tl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    # pad query rows (and the len-0 batch row) must be exactly zero
    for i, ln in enumerate(lens):
        if ln < S:
            assert float(jnp.abs(got[i, :, ln:]).max()) == 0.0


def test_flash_prefill_ragged_full_equals_plain():
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (2, 4, 64, 32), jnp.float32)
    k = rand(ks[1], (2, 2, 64, 32), jnp.float32)
    v = rand(ks[2], (2, 2, 64, 32), jnp.float32)
    tl = jnp.asarray([64, 64], jnp.int32)
    rag = flash_prefill_ragged(q, k, v, tl, block_q=16, block_k=16,
                               interpret=True)
    plain = flash_prefill(q, k, v, block_q=16, block_k=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(rag), np.asarray(plain))


# ------------------------------------------------------------ ragged extend
@pytest.mark.parametrize(
    "starts,lens,S,T",
    [
        ([0, 0], [32, 32], 32, 64),        # pure prefill into empty cache
        ([16, 48], [32, 17], 32, 96),      # extend mid-cache, ragged lens
        ([96, 5], [32, 0], 32, 128),       # frontier at the edge + empty row
        ([16, 40], [32, 17], 32, 90),      # T not a block multiple
    ],
)
def test_ragged_extend_matches_ref(starts, lens, S, T):
    B, H, Hkv, D = len(starts), 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, S, H, D), jnp.float32)
    k = rand(ks[1], (B, T, Hkv, D), jnp.float32)
    v = rand(ks[2], (B, T, Hkv, D), jnp.float32)
    st = jnp.asarray(starts, jnp.int32)
    tl = jnp.asarray(lens, jnp.int32)
    got = ragged_extend(q, k, v, st, tl, block_q=16, block_k=16,
                        interpret=True)
    want = ref.ragged_extend_ref(q, k, v, st, tl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    for i, ln in enumerate(lens):
        if ln < S:
            assert float(jnp.abs(got[i, ln:]).max()) == 0.0


# -------------------------------------------------------- counted traffic
def test_counting_trimmed_strictly_cheaper():
    """The analytic counters must show the trimmed grids moving strictly
    fewer KV bytes than their rectangular/full baselines — the regression
    invariant the kernel-regression CI job gates on."""
    from repro.kernels import counting

    tri = counting.flash_prefill_counts(1, 4, 2, 512, 64, block_q=64,
                                        block_k=64, variant="block_skip")
    rect = counting.flash_prefill_counts(1, 4, 2, 512, 64, block_q=64,
                                         block_k=64, variant="rect")
    assert tri["kv_bytes"] < rect["kv_bytes"]
    assert tri["flops"] == rect["flops"]  # same math, fewer fetches

    rag = counting.flash_prefill_counts(4, 4, 2, 512, 64, block_q=64,
                                        block_k=64,
                                        true_lens=[512, 300, 64, 0])
    full = counting.flash_prefill_counts(4, 4, 2, 512, 64, block_q=64,
                                         block_k=64, variant="block_skip")
    assert rag["kv_bytes"] < full["kv_bytes"]

    trim = counting.paged_attention_counts(4, 8, 2, 64, 16, 16,
                                           [256, 131, 7, 0], trimmed=True)
    dense = counting.paged_attention_counts(4, 8, 2, 64, 16, 16,
                                            [256, 131, 7, 0], trimmed=False)
    assert trim["kv_bytes"] < dense["kv_bytes"]

    ext = counting.ragged_extend_counts(2, 4, 2, 128, 512, 64, [0, 384],
                                        [128, 65], trimmed=True)
    ext_d = counting.ragged_extend_counts(2, 4, 2, 128, 512, 64, [0, 384],
                                          [128, 65], trimmed=False)
    assert ext["kv_bytes"] < ext_d["kv_bytes"]


def test_counting_fused_sgmv_single_pass():
    from repro.kernels import counting

    fused = counting.sgmv_counts(8, 256, 512, 512, 32, fused=True)
    unfused = counting.sgmv_counts(8, 256, 512, 512, 32, fused=False)
    assert fused["x_passes_per_block"] == 1.0
    assert unfused["x_passes_per_block"] == 2.0
    assert fused["kernel_launches"] == 1
    assert unfused["kernel_launches"] == 2
