"""Differential serving tests: kernel_backend="pallas" vs "jnp".

The Pallas hot-loop kernels (block-skip flash prefill, length-trimmed paged
decode, ragged extend, fused base+LoRA SGMV) must be TOKEN-IDENTICAL to the
jnp einsum pin end-to-end — same requests, same engine state machine, only
the attention/projection kernels swapped. Runs on the reduced qwen3 config:
GQA (so the grouped kv index maps are exercised), no logit softcap and no
sliding window (those route to the jnp fallback by design, see
models/attention.py).
"""

import itertools

import jax
import pytest

from repro import configs
from repro.serving import EngineConfig, Request, ServingEngine

_ids = itertools.count()

SYS = tuple(range(40, 52))  # 12-token "system prompt" (3 blocks of 4)


def req(adapter, prompt, n=4, shared=0):
    return Request(f"kb{next(_ids)}", adapter, tuple(prompt),
                   max_new_tokens=n, shared_prefix_len=shared)


def make_engine(backend: str, **kw):
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(
        hbm_bytes=8 << 20,
        host_bytes=32 << 20,
        block_size=4,
        max_batch_slots=4,
        max_seq_len=96,
        kernel_backend=backend,
        **kw,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(11))
    for i in range(3):
        eng.register_adapter(f"lora-{i}")
    return eng


def run_workload(backend: str, **engine_kw) -> list[tuple[int, ...]]:
    """A workload that exercises every pallas call site: multi-adapter
    prefill (ragged buckets), shared-prefix base-model rows (negative
    adapter ids through fused_sgmv), and decode steps (paged kernel)."""
    eng = make_engine(backend, **engine_kw)
    reqs = [
        req("lora-0", SYS + tuple(range(60, 65)), n=4, shared=len(SYS)),
        req("lora-1", SYS + tuple(range(70, 73)), n=4, shared=len(SYS)),
        req("lora-2", range(80, 87), n=3),  # fully adapter-specific
        req("lora-0", range(90, 104), n=3),  # longer ragged row
    ]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(len(r.generated) > 0 for r in reqs)
    return [tuple(r.generated) for r in reqs]


@pytest.mark.parametrize(
    "engine_kw",
    [
        dict(schedule_mode="mixed"),
        dict(schedule_mode="alternate"),
        dict(schedule_mode="alternate", prefill_mode="eager"),
    ],
    ids=["mixed", "alternate", "eager"],
)
def test_pallas_tokens_identical_to_jnp(engine_kw):
    jnp_tokens = run_workload("jnp", **engine_kw)
    pallas_tokens = run_workload("pallas", **engine_kw)
    assert pallas_tokens == jnp_tokens, (
        f"kernel backend changed generation under {engine_kw}"
    )


def test_pallas_prefix_reuse_identical():
    """The warm path (decode against reused cache KV) must also agree: the
    paged kernel reads exactly the KV the jnp path would."""
    tokens = {}
    for backend in ("jnp", "pallas"):
        eng = make_engine(backend)
        r1 = req("lora-0", range(10, 26), n=6)
        eng.submit(r1)
        eng.run()
        r2 = req("lora-0", r1.full_tokens, n=4)
        eng.submit(r2)
        eng.run()
        assert r2.matched_tokens > 0
        tokens[backend] = (tuple(r1.generated), tuple(r2.generated))
    assert tokens["pallas"] == tokens["jnp"]


def test_invalid_backend_rejected():
    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    ecfg = EngineConfig(hbm_bytes=8 << 20, host_bytes=32 << 20, block_size=4,
                        max_batch_slots=4, max_seq_len=96,
                        kernel_backend="triton")
    with pytest.raises(ValueError, match="kernel_backend"):
        ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(0))
