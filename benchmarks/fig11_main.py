"""Fig. 11 — main result: TTFT / TPOT / peak throughput, FASTLIBRA vs
vLLM vs S-LoRA across scenarios, model sizes and adapter counts.

Methodology follows §6.3: for each (model, #LoRA, system) we sweep sending
rates from low load up to ~peak and report the average TTFT/TPOT over the
sweep, plus the 500 ms-SLO peak throughput.
"""

from .common import CsvOut, QUICK, emit_report, peak_throughput, run_sweep

SYSTEMS = ("fastlibra", "vllm", "slora")


def run(out: CsvOut) -> None:
    grid = [("llama-7b", n) for n in ((20, 100) if QUICK else (20, 50, 100))]
    if not QUICK:
        grid += [("llama-13b", 50), ("llama-34b", 50)]
    results = {}
    for scenario in ("chatbot", "translation", "agent"):
        for model, n_loras in grid:
            for sysname in SYSTEMS:
                ttft, tpot, _ = run_sweep(model, scenario, sysname, n_loras)
                results[(scenario, model, n_loras, sysname)] = (ttft, tpot)
                emit_report(
                    out,
                    f"fig11/{scenario}/{model.split('-')[1]}-{n_loras}/{sysname}/ttft",
                    ttft * 1e6,
                    {"tpot_ms": tpot * 1e3},
                    ("tpot_ms:.2f",),
                )
    # paper headline: average reduction vs each baseline
    for base in ("vllm", "slora"):
        red_ttft, red_tpot = [], []
        for key, (ttft, tpot) in results.items():
            if key[3] != "fastlibra":
                continue
            b = results.get((key[0], key[1], key[2], base))
            if b and b[0] > 0:
                red_ttft.append(1.0 - ttft / b[0])
            if b and b[1] > 0:
                red_tpot.append(1.0 - tpot / b[1])
        if red_ttft:
            out.emit(
                f"fig11/summary/ttft_reduction_vs_{base}",
                sum(red_ttft) / len(red_ttft) * 100,
                f"paper=60.3%_vllm/50.1%_slora;tpot_red="
                f"{sum(red_tpot)/len(red_tpot)*100:.1f}%",
            )
    # peak throughput (7B-50, chatbot)
    peaks = {}
    for sysname in SYSTEMS:
        peaks[sysname] = peak_throughput("llama-7b", "chatbot", sysname, 50)
        out.emit(f"fig11/peak_qps/chatbot/7b-50/{sysname}", peaks[sysname] * 1e6,
                 "ttft_slo=500ms")
    for base in ("vllm", "slora"):
        if peaks[base] > 0:
            out.emit(f"fig11/summary/peak_vs_{base}",
                     peaks["fastlibra"] / peaks[base],
                     "paper=1.7x_vllm/1.6x_slora")
    # engine-level TTFT cross-check (real JAX execution on the reduced
    # arch): the bucketed prefill subsystem vs the eager seed path
    from . import prefill_bench

    prefill_bench.run(out, prefix="fig11/engine_prefill")
