"""Per-kernel regression harness: parity, counted HBM traffic, roofline.

Each shape emits two CSV rows — ``…/interpret`` (Pallas interpret mode, the
kernel body running in Python; available everywhere) and ``…/compiled``
(Mosaic-compiled Pallas on TPU; on CPU the jit'd jnp reference stands in,
flagged ``impl=ref_jnp``). Timing in interpret mode validates plumbing, not
speed — the performance claims are *counted*, not timed: the ``derived``
column carries analytic bytes/FLOPs from ``repro.kernels.counting`` (replay
of the exact trimmed grids and index-map clamps) plus the roofline terms
from ``benchmarks.roofline.kernel_roofline``. ``--check`` turns the harness
into a gate: parity vs the jnp oracles, trimmed grids strictly cheaper than
their rectangular/full baselines, fused SGMV exactly one pass over the
activation tile, zero-length paged rows exactly zero. See README.md §Kernels.

Usage:
    PYTHONPATH=src python -m benchmarks.kernels_bench [--quick] [--check]
        [--csv PATH]
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.kernels import (
    counting,
    flash_prefill,
    flash_prefill_ragged,
    fused_sgmv,
    paged_attention,
    ragged_extend,
    ref,
    sgmv,
)

from .common import CsvOut, fmt_fields
from .roofline import kernel_roofline

ON_TPU = jax.default_backend() == "tpu"


def _time(fn, *args, reps: int = 3, **kw) -> float:
    """Mean µs/call after a warmup call (compile excluded)."""
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def _err(a, b) -> float:
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


def _roofline_tag(counts: dict, t_us: float | None = None) -> str:
    rl = kernel_roofline(counts["flops"], counts.get("hbm_bytes",
                                                     counts.get("x_bytes", 0)),
                         measured_us=t_us if ON_TPU else None)
    fields = ["bound_us:.2f", "dom=dominant", "ceiling_frac=ceiling_fraction:.3f"]
    if "achieved_fraction" in rl:
        fields.append("achieved_frac=achieved_fraction:.3f")
    return fmt_fields(rl, fields)


def _emit_pair(out: CsvOut, name: str, kernel_fn, ref_fn, args, kw,
               derived: str) -> tuple[float, float]:
    """Emit interpret + compiled rows for one shape.

    Returns (parity_err, compiled_us) — the compiled timing is real Pallas
    only on TPU (elsewhere the jnp reference stands in and floors must not
    be pinned against it).
    """
    t_int = _time(kernel_fn, *args, interpret=True, **kw)
    got = kernel_fn(*args, interpret=True, **kw)
    want = ref_fn(*args)
    err = _err(got, want)
    out.emit(f"{name}/interpret", t_int, f"err={err:.2e};{derived}")
    if ON_TPU:
        t_cmp = _time(kernel_fn, *args, interpret=False, **kw)
        out.emit(f"{name}/compiled", t_cmp, f"impl=pallas;{derived}")
    else:
        t_cmp = _time(jax.jit(ref_fn), *args)
        out.emit(f"{name}/compiled", t_cmp, f"impl=ref_jnp;{derived}")
    return err, t_cmp


# Achieved-vs-roofline floors (fraction of the counted roofline bound the
# compiled kernel must reach). Only meaningful against real Mosaic timings,
# so --floors is a no-op off-TPU. Conservative on purpose: they catch
# regressions that fall off a cliff (lost block-skip, serialized grid), not
# single-digit-percent drift.
FLOORS = {
    "fused_sgmv": 0.20,
    "flash_prefill": 0.30,
    "flash_prefill_ragged": 0.20,
    "paged_attention": 0.10,
    "ragged_extend": 0.20,
}


class Checks:
    def __init__(self, floors: bool = False) -> None:
        self.failures: list[str] = []
        self.floors = floors and ON_TPU

    def expect(self, ok: bool, msg: str) -> None:
        if not ok:
            self.failures.append(msg)
            print(f"CHECK FAIL: {msg}", file=sys.stderr)

    def floor(self, kernel: str, counts: dict, compiled_us: float) -> None:
        if not self.floors:
            return
        rl = kernel_roofline(counts["flops"], counts["hbm_bytes"],
                             measured_us=compiled_us)
        got = rl["achieved_fraction"]
        self.expect(got >= FLOORS[kernel],
                    f"{kernel}: achieved roofline fraction {got:.3f} below "
                    f"floor {FLOORS[kernel]}")


def bench_sgmv(out: CsvOut, checks: Checks, quick: bool) -> None:
    key = jax.random.PRNGKey(0)
    # (label, B, S, d_in, r, d_out, n_slots) — decode batch + prefill tile
    shapes = [("decode", 8, 1, 512, 32, 512, 8)]
    if not quick:
        shapes.append(("prefill", 4, 256, 256, 16, 512, 8))
    for label, B, S, d, r, o, N in shapes:
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
        w = jax.random.normal(ks[1], (d, o), jnp.float32) * 0.05
        a = jax.random.normal(ks[2], (N, d, r), jnp.float32) * 0.05
        b = jax.random.normal(ks[3], (N, r, o), jnp.float32) * 0.05
        ids = jax.random.randint(ks[4], (B,), -1, N)  # incl. base-model rows
        cf = counting.sgmv_counts(B, S, d, o, r, fused=True)
        cu = counting.sgmv_counts(B, S, d, o, r, fused=False)
        derived = (f"B={B};S={S};d={d};r={r};o={o};"
                   f"x_passes={cf['x_passes_per_block']:.1f};"
                   f"unfused_x_passes={cu['x_passes_per_block']:.1f};"
                   f"launches={cf['kernel_launches']};" + _roofline_tag(cf))
        err, t_cmp = _emit_pair(out, f"kernels/fused_sgmv/{label}", fused_sgmv,
                                ref.fused_sgmv_ref, (x, w, a, b, ids), {},
                                derived)
        checks.expect(err < 1e-4, f"fused_sgmv/{label} parity err={err:.2e}")
        checks.floor("fused_sgmv", {**cf, "hbm_bytes": cf["x_bytes"]}, t_cmp)
        checks.expect(cf["x_passes_per_block"] == 1.0,
                      f"fused_sgmv/{label} x_passes={cf['x_passes_per_block']}"
                      " (want exactly 1 pass over the activation tile)")
        checks.expect(cu["x_passes_per_block"] == 2.0,
                      f"unfused sgmv/{label} baseline x_passes="
                      f"{cu['x_passes_per_block']} (want 2)")
        # unfused pair (legacy path) for the timing comparison row
        t_unf = _time(sgmv, x, a, b, ids, interpret=True)
        out.emit(f"kernels/sgmv/{label}/interpret", t_unf,
                 f"delta_only=1;pair_of=fused_sgmv/{label}")


def bench_flash(out: CsvOut, checks: Checks, quick: bool) -> None:
    key = jax.random.PRNGKey(1)
    # (label, S, block) — incl. a long-causal shape where block-skip pays
    shapes = [("S256", 256, 64)]
    if not quick:
        shapes.append(("long_S1024", 1024, 128))
    B, H, Hkv, D = 1, 4, 2, 64
    for label, S, blk in shapes:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
        tri = counting.flash_prefill_counts(B, H, Hkv, S, D, block_q=blk,
                                            block_k=blk, variant="block_skip")
        rect = counting.flash_prefill_counts(B, H, Hkv, S, D, block_q=blk,
                                             block_k=blk, variant="rect")
        ratio = tri["kv_bytes"] / rect["kv_bytes"]
        derived = (f"S={S};blk={blk};kv_bytes={tri['kv_bytes']};"
                   f"rect_kv_bytes={rect['kv_bytes']};kv_ratio={ratio:.3f};"
                   + _roofline_tag(tri))
        err, t_cmp = _emit_pair(out, f"kernels/flash_prefill/{label}",
                                flash_prefill, ref.flash_prefill_ref, (q, k, v),
                                dict(block_q=blk, block_k=blk), derived)
        checks.expect(err < 1e-4, f"flash_prefill/{label} parity err={err:.2e}")
        checks.floor("flash_prefill", tri, t_cmp)
        checks.expect(tri["kv_bytes"] < rect["kv_bytes"],
                      f"flash_prefill/{label}: block-skip kv_bytes "
                      f"{tri['kv_bytes']} not < rect {rect['kv_bytes']}")
        checks.expect(tri["flops"] == rect["flops"],
                      f"flash_prefill/{label}: schedules disagree on flops")


def bench_flash_ragged(out: CsvOut, checks: Checks, quick: bool) -> None:
    key = jax.random.PRNGKey(2)
    B, H, Hkv, D, blk = 4, 4, 2, 64, 64
    S = 256 if quick else 512
    lens = [S, (S * 5) // 8, blk // 2, 0]  # bucket: full, partial, tiny, empty
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    tl = jnp.array(lens, jnp.int32)
    rag = counting.flash_prefill_counts(B, H, Hkv, S, D, block_q=blk,
                                        block_k=blk, true_lens=lens)
    full = counting.flash_prefill_counts(B, H, Hkv, S, D, block_q=blk,
                                         block_k=blk, variant="block_skip")
    ratio = rag["kv_bytes"] / full["kv_bytes"]
    derived = (f"S={S};lens={'/'.join(map(str, lens))};"
               f"kv_bytes={rag['kv_bytes']};full_kv_bytes={full['kv_bytes']};"
               f"kv_ratio={ratio:.3f};" + _roofline_tag(rag))
    err, t_cmp = _emit_pair(out, "kernels/flash_prefill_ragged/bucket",
                            flash_prefill_ragged, ref.flash_prefill_ragged_ref,
                            (q, k, v, tl), dict(block_q=blk, block_k=blk),
                            derived)
    checks.expect(err < 1e-4, f"flash_prefill_ragged parity err={err:.2e}")
    checks.floor("flash_prefill_ragged", rag, t_cmp)
    checks.expect(rag["kv_bytes"] < full["kv_bytes"],
                  f"flash_prefill_ragged: trimmed kv_bytes {rag['kv_bytes']} "
                  f"not < full-length {full['kv_bytes']}")
    zero_rows = flash_prefill_ragged(q, k, v, tl, block_q=blk, block_k=blk,
                                     interpret=True)[3]
    checks.expect(float(jnp.max(jnp.abs(zero_rows))) == 0.0,
                  "flash_prefill_ragged: len-0 row not exactly zero")


def bench_paged(out: CsvOut, checks: Checks, quick: bool) -> None:
    key = jax.random.PRNGKey(3)
    B, H, Hkv, D, ps, pages = 4, 8, 2, 64, 16, 4 if quick else 16
    n_pages = B * pages * 2
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (n_pages, ps, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (n_pages, ps, Hkv, D), jnp.float32)
    tables = jax.random.permutation(ks[3], n_pages)[: B * pages]
    tables = tables.reshape(B, pages).astype(jnp.int32)
    T = ps * pages
    lens = [T, T // 2 + 3, 7, 0]  # incl. non-multiple of page_size and empty
    ln = jnp.array(lens, jnp.int32)
    trim = counting.paged_attention_counts(B, H, Hkv, D, ps, pages, lens,
                                           trimmed=True)
    full = counting.paged_attention_counts(B, H, Hkv, D, ps, pages, lens,
                                           trimmed=False)
    ratio = trim["kv_bytes"] / full["kv_bytes"]
    derived = (f"B={B};pages={pages}x{ps};lens={'/'.join(map(str, lens))};"
               f"kv_bytes={trim['kv_bytes']};full_kv_bytes={full['kv_bytes']};"
               f"kv_ratio={ratio:.3f};" + _roofline_tag(trim))
    err, t_cmp = _emit_pair(out, "kernels/paged_attention/decode",
                            paged_attention, ref.paged_attention_ref,
                            (q, kp, vp, tables, ln), {}, derived)
    checks.expect(err < 1e-4, f"paged_attention parity err={err:.2e}")
    checks.floor("paged_attention", trim, t_cmp)
    checks.expect(trim["kv_bytes"] < full["kv_bytes"],
                  f"paged_attention: trimmed kv_bytes {trim['kv_bytes']} "
                  f"not < full-grid {full['kv_bytes']}")
    zero_row = paged_attention(q, kp, vp, tables, ln, interpret=True)[3]
    checks.expect(float(jnp.max(jnp.abs(zero_row))) == 0.0,
                  "paged_attention: len-0 row not exactly zero "
                  "(historical bug: softmax of all-masked row gave mean(V))")


def bench_ragged_extend(out: CsvOut, checks: Checks, quick: bool) -> None:
    key = jax.random.PRNGKey(4)
    B, H, Hkv, D, blk = 4, 4, 2, 64, 64
    S = 128 if quick else 256  # new-token bucket
    T = 512 if quick else 1024  # padded cache rectangle
    starts = [0, T // 4, T - S, 5]
    lens = [S, S // 2 + 1, S, 0]
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), jnp.float32)
    st = jnp.array(starts, jnp.int32)
    tl = jnp.array(lens, jnp.int32)
    trim = counting.ragged_extend_counts(B, H, Hkv, S, T, D, starts, lens,
                                         block_q=blk, block_k=blk, trimmed=True)
    dense = counting.ragged_extend_counts(B, H, Hkv, S, T, D, starts, lens,
                                          block_q=blk, block_k=blk,
                                          trimmed=False)
    ratio = trim["kv_bytes"] / dense["kv_bytes"]
    derived = (f"S={S};T={T};starts={'/'.join(map(str, starts))};"
               f"lens={'/'.join(map(str, lens))};kv_bytes={trim['kv_bytes']};"
               f"dense_kv_bytes={dense['kv_bytes']};kv_ratio={ratio:.3f};"
               + _roofline_tag(trim))
    err, t_cmp = _emit_pair(out, "kernels/ragged_extend/bucket", ragged_extend,
                            ref.ragged_extend_ref, (q, k, v, st, tl),
                            dict(block_q=blk, block_k=blk), derived)
    checks.expect(err < 1e-4, f"ragged_extend parity err={err:.2e}")
    checks.floor("ragged_extend", trim, t_cmp)
    checks.expect(trim["kv_bytes"] < dense["kv_bytes"],
                  f"ragged_extend: trimmed kv_bytes {trim['kv_bytes']} "
                  f"not < dense rectangle {dense['kv_bytes']}")


def run(out: CsvOut, *, quick: bool | None = None,
        checks: Checks | None = None) -> Checks:
    """benchmarks.run adapter; also the --check engine."""
    import os

    if quick is None:
        quick = os.environ.get("BENCH_QUICK", "0") == "1"
    if checks is None:
        checks = Checks()
    bench_sgmv(out, checks, quick)
    bench_flash(out, checks, quick)
    bench_flash_ragged(out, checks, quick)
    bench_paged(out, checks, quick)
    bench_ragged_extend(out, checks, quick)
    return checks


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--quick", action="store_true",
                   help="small shapes only (CI interpret-mode budget)")
    p.add_argument("--check", action="store_true",
                   help="assert parity + counted-traffic invariants; "
                        "exit nonzero on any failure")
    p.add_argument("--floors", action="store_true",
                   help="additionally pin achieved-vs-roofline floors "
                        "(needs a TPU; no-op on CPU, where compiled timings "
                        "are the jnp stand-in)")
    p.add_argument("--csv", default="",
                   help="also write the rows to this path")
    args = p.parse_args(argv)
    out = CsvOut()
    print("name,us_per_call,derived")
    checks = run(out, quick=args.quick, checks=Checks(floors=args.floors))
    if args.csv:
        out.write_csv(args.csv)
        print(f"# wrote {len(out.rows)} rows to {args.csv}", file=sys.stderr)
    if args.check:
        if checks.failures:
            print(f"# {len(checks.failures)} check(s) FAILED", file=sys.stderr)
            return 1
        print("# all kernel checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
