"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp oracle.

On this CPU container the numbers validate plumbing, not TPU speed; the
roofline analysis (benchmarks/roofline.py) covers projected TPU performance.
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_prefill, paged_attention, ref, sgmv

from .common import CsvOut


def _time(fn, *args, reps=5, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps * 1e6


def run(out: CsvOut) -> None:
    key = jax.random.PRNGKey(0)
    # sgmv: decode-shaped batch
    B, S, d, r, o, N = 8, 1, 512, 32, 512, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    a = jax.random.normal(ks[1], (N, d, r), jnp.float32)
    b = jax.random.normal(ks[2], (N, r, o), jnp.float32)
    ids = jax.random.randint(ks[3], (B,), 0, N)
    t_k = _time(sgmv, x, a, b, ids, interpret=True)
    t_r = _time(ref.sgmv_ref, x, a, b, ids)
    out.emit("kernels/sgmv_decode", t_k, f"ref_us={t_r:.1f};B={B};d={d};r={r}")
    # paged attention
    q = jax.random.normal(ks[0], (4, 8, 64), jnp.float32)
    kp = jax.random.normal(ks[1], (32, 16, 2, 64), jnp.float32)
    vp = jax.random.normal(ks[2], (32, 16, 2, 64), jnp.float32)
    tables = jax.random.permutation(ks[3], 32)[:16].reshape(4, 4).astype(jnp.int32)
    lens = jnp.array([64, 50, 33, 7], jnp.int32)
    t_k = _time(paged_attention, q, kp, vp, tables, lens, interpret=True)
    t_r = _time(ref.paged_attention_ref, q, kp, vp, tables, lens)
    out.emit("kernels/paged_attention", t_k, f"ref_us={t_r:.1f};B=4;pages=4x16")
    # flash prefill
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    t_k = _time(flash_prefill, q, k, v, block_q=64, block_k=64, interpret=True)
    t_r = _time(ref.flash_prefill_ref, q, k, v)
    out.emit("kernels/flash_prefill", t_k, f"ref_us={t_r:.1f};S=256;D=64")
