"""Roofline benchmark: reads the dry-run artifacts (results/dryrun/*.json)
and emits the three roofline terms per (arch × shape × mesh) cell.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values fixed by the assignment).
"""

from __future__ import annotations

import json
import pathlib

from .common import CsvOut

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"

PEAK_FLOPS = 197e12  # per chip, bf16
HBM_BW = 819e9  # per chip
ICI_BW = 50e9  # per link


def kernel_roofline(flops: float, hbm_bytes: float,
                    measured_us: float | None = None) -> dict:
    """Single-chip roofline terms for one kernel invocation.

    ``flops``/``hbm_bytes`` come from ``repro.kernels.counting`` — analytic
    replay of the trimmed grids, not a profiler. ``ceiling_fraction`` is the
    best MXU utilization the counted traffic admits (t_comp / bound ≤ 1);
    ``achieved_fraction`` (when a measured TPU time is supplied) is
    bound / measured — how close the run came to its own roofline. CI pins
    floors on these for the kernel-regression job (TPU-only for achieved;
    the counted ceiling is hardware-independent). See README.md §Kernels.
    """
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm_bytes / HBM_BW
    bound = max(t_comp, t_mem, 1e-15)
    out = {
        "t_compute": t_comp,
        "t_memory": t_mem,
        "bound_us": bound * 1e6,
        "dominant": "compute" if t_comp >= t_mem else "memory",
        "ceiling_fraction": t_comp / bound,
    }
    if measured_us is not None and measured_us > 0:
        out["achieved_fraction"] = min(1.0, bound * 1e6 / measured_us)
    return out


def roofline_terms(rec: dict) -> dict:
    chips = rec["num_devices"]
    t_comp = rec["flops"] / (chips * PEAK_FLOPS)
    # memory term: prefer the structural entry-only estimate (TPU-realistic);
    # XLA-CPU cost_analysis bytes count unfused elementwise chains a TPU
    # would fuse (recorded for reference as bytes_accessed).
    mem_bytes_dev = rec.get(
        "bytes_entry_per_device", rec["bytes_accessed"] / chips
    )
    t_mem = mem_bytes_dev / HBM_BW
    # collective_bytes is per-device link traffic (parsed from the
    # partitioned HLO), so the term is bytes/dev over the per-link bw
    t_coll = rec["collective_bytes"] / ICI_BW
    dom = max(
        ("compute", t_comp), ("memory", t_mem), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "t_compute": t_comp,
        "t_memory": t_mem,
        "t_collective": t_coll,
        "dominant": dom,
        "bound": max(t_comp, t_mem, t_coll),
    }
    if rec.get("model_flops"):
        out["useful_flops_ratio"] = rec["model_flops"] / max(1.0, rec["flops"])
        # roofline fraction: useful work / (what the dominant term costs)
        out["roofline_fraction"] = (
            rec["model_flops"] / (chips * PEAK_FLOPS)
        ) / max(1e-12, out["bound"])
    return out


def load_records() -> list[dict]:
    if not RESULTS.exists():
        raise FileNotFoundError(f"{RESULTS} (run launch/dryrun.py first)")
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        rec["_file"] = f.name
        recs.append(rec)
    if not recs:
        raise FileNotFoundError(f"{RESULTS} is empty (run launch/dryrun.py)")
    return recs


MITIGATION = {
    "compute": "raise MXU utilization: larger fused matmul tiles / bf16 IO",
    "memory": "cut HBM traffic: blockwise attention (q_chunk), int8 KV, "
              "remat policy 'dots'",
    "collective": "re-shard to shrink cross-device traffic: fewer all-gathers "
                  "(fsdp prefetch), hierarchical pod-axis reduce, int8 grads",
}


def markdown_table(records: list[dict]) -> str:
    """Curated §Roofline table for EXPERIMENTS.md."""
    lines = [
        "| arch | shape | mesh | t_comp s | t_mem s | t_coll s | dominant | "
        "useful | roofline-frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        tag = f"×{rec['opts']}" if rec.get("opts") else ""
        if rec.get("status") != "ok":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']}{tag} | - | - | - "
                f"| - | - | - | {rec['status'][:60]} |"
            )
            continue
        t = roofline_terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']}{tag} "
            f"| {t['t_compute']:.3f} | {t['t_memory']:.3f} "
            f"| {t['t_collective']:.3f} | {t['dominant']} "
            f"| {t.get('useful_flops_ratio', 0):.3f} "
            f"| {t.get('roofline_fraction', 0):.4f} "
            f"| {MITIGATION[t['dominant']][:48]} |"
        )
    return "\n".join(lines)


def main() -> None:
    print(markdown_table(load_records()))


def run(out: CsvOut) -> None:
    for rec in load_records():
        if rec.get("status") != "ok":
            out.emit(
                f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                0.0,
                f"status={rec.get('status')}",
            )
            continue
        terms = roofline_terms(rec)
        out.emit(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            terms["bound"] * 1e6,
            f"dom={terms['dominant']};comp_s={terms['t_compute']:.2e};"
            f"mem_s={terms['t_memory']:.2e};coll_s={terms['t_collective']:.2e};"
            f"useful={terms.get('useful_flops_ratio', 0):.3f};"
            f"roofline_frac={terms.get('roofline_fraction', 0):.3f}",
        )
if __name__ == "__main__":
    main()
