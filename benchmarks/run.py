"""Benchmark orchestrator — one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run              # full pass
    PYTHONPATH=src python -m benchmarks.run fig11 fig15  # subset
    BENCH_QUICK=1 PYTHONPATH=src python -m benchmarks.run

Prints ``name,us_per_call,derived`` CSV rows (harness convention); the
roofline benchmark (reads dry-run artifacts) lives in benchmarks/roofline.py
and is included when its inputs exist.
"""

from __future__ import annotations

import sys
import time

from .common import CsvOut

MODULES = [
    ("fig2", "benchmarks.fig2_vllm_ttft"),
    ("fig5", "benchmarks.fig5_correlation"),
    ("fig9", "benchmarks.fig9_lora_ratio"),
    ("fig11", "benchmarks.fig11_main"),
    ("fig12", "benchmarks.fig12_breakdown"),
    ("fig13", "benchmarks.fig13_hbm_hit"),
    ("fig14", "benchmarks.fig14_alloc_time"),
    ("fig15", "benchmarks.fig15_ablations"),
    ("fig16", "benchmarks.fig16_many_lora"),
    ("overhead", "benchmarks.overhead"),
    ("prefill", "benchmarks.prefill_bench"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    import importlib

    selected = set(sys.argv[1:])
    out = CsvOut()
    print("name,us_per_call,derived")
    for name, modpath in MODULES:
        if selected and name not in selected:
            continue
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(modpath)
            mod.run(out)
        except FileNotFoundError as e:
            print(f"{name}/SKIPPED,0.0,missing_input={e}")
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
            raise
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
