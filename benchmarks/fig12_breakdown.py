"""Fig. 12 — TTFT breakdown: queue / LoRA cold-start / KV cold-start."""

from .common import CsvOut, emit_report, run_sim


def run(out: CsvOut) -> None:
    for scenario in ("chatbot", "translation", "agent"):
        for sysname in ("fastlibra", "vllm", "slora"):
            res = run_sim("llama-7b", scenario, sysname, n_loras=50)
            s = res.summary()
            emit_report(
                out,
                f"fig12/{scenario}/{sysname}/breakdown",
                res.avg_ttft * 1e6,
                {
                    "queue_ms": s["avg_queue"] * 1e3,
                    "lora_cold_ms": s["avg_lora_cold"] * 1e3,
                    "kv_cold_ms": s["avg_kv_cold"] * 1e3,
                },
                ("queue_ms:.2f", "lora_cold_ms:.2f", "kv_cold_ms:.2f"),
            )
