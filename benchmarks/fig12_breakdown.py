"""Fig. 12 — TTFT breakdown: queue / LoRA cold-start / KV cold-start."""

from .common import CsvOut, run_sim


def run(out: CsvOut) -> None:
    for scenario in ("chatbot", "translation", "agent"):
        for sysname in ("fastlibra", "vllm", "slora"):
            res = run_sim("llama-7b", scenario, sysname, n_loras=50)
            out.emit(
                f"fig12/{scenario}/{sysname}/breakdown",
                res.avg_ttft * 1e6,
                f"queue_ms={res.avg_queue*1e3:.2f};"
                f"lora_cold_ms={res.avg_lora_coldstart*1e3:.2f};"
                f"kv_cold_ms={res.avg_kv_coldstart*1e3:.2f}",
            )
