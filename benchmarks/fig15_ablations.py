"""Fig. 15 — ablations: FASTLIBRA-WOM / -WOS / -WOL normalized TTFT/TPOT.

Also reports the paper-literal Eval ordering (fastlibra-paper) vs the
density-ordering correction (EXPERIMENTS.md §Perf-policy).
"""

from .common import CsvOut, run_sim


def run(out: CsvOut) -> None:
    # 300 adapters: enough inter-LoRA pressure that dependency maintenance
    # and the LoRA-quantity reward have something to do (paper uses dynamic
    # production-trace popularity for the same reason)
    for scenario in ("chatbot", "translation", "agent"):
        base = run_sim("llama-7b", scenario, "fastlibra", n_loras=300)
        for variant in ("wom", "wos", "wol", "fastlibra-paper"):
            res = run_sim("llama-7b", scenario, variant, n_loras=300)
            nt = res.avg_ttft / max(1e-9, base.avg_ttft)
            np_ = res.avg_tpot / max(1e-9, base.avg_tpot)
            extra = ""
            if variant == "wom":
                extra = f";invalid_kv={res.summary()['avg_invalid_kv']:.3f}"
            out.emit(
                f"fig15/{scenario}/{variant}",
                res.avg_ttft * 1e6,
                f"norm_ttft={nt:.3f};norm_tpot={np_:.3f}{extra}",
            )
