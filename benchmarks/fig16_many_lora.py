"""Fig. 16 — 1000/2000 adapters under uniform / distinct / skewed loads."""

from .common import CsvOut, QUICK, run_sim


def run(out: CsvOut) -> None:
    counts = (1000,) if QUICK else (1000, 2000)
    dists = ("uniform", "distinct", "skewed")
    for n in counts:
        for dist in dists:
            for sysname in ("fastlibra", "vllm", "slora"):
                res = run_sim(
                    "llama-7b", "chatbot", sysname, n_loras=n, dist=dist,
                    duration=120.0 if QUICK else 240.0,
                )
                out.emit(
                    f"fig16/{n}-{dist}/{sysname}",
                    res.avg_ttft * 1e6,
                    f"tpot_ms={res.avg_tpot*1e3:.2f};"
                    f"lora_hit={res.summary()['lora_hit_rate']:.3f}",
                )
