"""§6.10 — FASTLIBRA's own overheads measured on the REAL manager:

* dependency-tree match+update under a full tree (paper: < 0.5 ms)
* one cache-swapper decision sweep (paper: < 5 ms)
"""

import time

from repro.core import make_fastlibra

from .common import CsvOut


def run(out: CsvOut) -> None:
    kvb = 524288  # llama-7b bytes/token
    mgr, sw = make_fastlibra(
        48 << 30, 192 << 30, kv_bytes_per_token=kvb, block_size=32
    )
    # populate: 100 LoRAs, 2000 conversations x ~512 tokens
    for i in range(100):
        mgr.register_lora(f"l{i}", 64 << 20, now=0.0)
    now = 1.0
    convs = []
    for c in range(2000):
        toks = tuple(c * 100000 + i for i in range(512))
        lid = f"l{c % 100}"
        lk = mgr.lookup(lid, toks, now)
        adm = mgr.admit(lk, now)
        if adm.queued:
            continue
        if mgr.allocate_running(f"q{c}", 512 - lk.match.matched_tokens + 64, now) is None:
            mgr.unpin(adm.pinned)
            continue
        mgr.commit(f"q{c}", lk, toks + tuple(-c * 100 - i for i in range(64)), now)
        mgr.unpin(adm.pinned)
        convs.append((lid, toks))
        now += 0.01
    n_nodes = sum(1 for _ in mgr.tree.iter_nodes())
    # ---- match/update latency over the full tree
    t0 = time.perf_counter()
    reps = 200
    for i in range(reps):
        lid, toks = convs[i % len(convs)]
        mgr.tree.match(lid, toks, now)
    match_us = (time.perf_counter() - t0) / reps * 1e6
    out.emit("overhead/tree_match", match_us,
             f"nodes={n_nodes};paper_budget_us=500")
    # ---- swapper decision sweep
    sw.observe_batch_size(16.0)
    t0 = time.perf_counter()
    reps = 50
    for i in range(reps):
        mgr.scorer.refresh(now)
        cands = mgr.evict_candidates()
        cands.sort(key=lambda n: mgr.scorer.score(n, now))
    sweep_us = (time.perf_counter() - t0) / reps * 1e6
    out.emit("overhead/swapper_decision", sweep_us,
             f"candidates={len(cands)};paper_budget_us=5000")
