"""Prefill hot-path benchmark: bucketed vs eager TTFT on a 32-request
multi-LoRA trace (real JAX execution on the reduced arch).

The eager seed path compiles one XLA executable per distinct suffix length
and dispatches one full-batch ``extend`` per admitted request; the bucketed
subsystem (serving/prefill.py) compiles at most ``len(buckets)`` shapes and
coalesces same-step admissions into one call. Mean TTFT over the trace is
the paper's headline metric (Fig. 11); this bench isolates the prefill
contribution on identical workloads.
"""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.serving import EngineConfig, Request, ServingEngine

N_REQUESTS = 32
N_LORAS = 8


def _engine(mode: str):
    import dataclasses

    import jax

    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    cfg = dataclasses.replace(
        cfg, lora=dataclasses.replace(cfg.lora, max_adapters=N_LORAS))
    ecfg = EngineConfig(
        hbm_bytes=16 << 20, host_bytes=64 << 20, block_size=4,
        max_batch_slots=8, max_seq_len=160,
        prefill_mode=mode, prefill_chunk=64, prefill_min_bucket=8,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(0))
    for i in range(N_LORAS):
        eng.register_adapter(f"lora-{i}")
    return eng


def _trace(seed: int = 0) -> list[Request]:
    """32 requests, zipf-distributed adapters, prompt lengths spanning every
    bucket (8..96 tokens) — the multi-LoRA many-distinct-lengths regime."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(N_REQUESTS):
        adapter = f"lora-{min(rng.zipf(1.5) - 1, N_LORAS - 1)}"
        plen = int(rng.choice([8, 11, 17, 23, 33, 47, 64, 96]))
        prompt = tuple(int(t) for t in rng.randint(1, 900, size=plen))
        reqs.append(Request(f"pb{seed}-{i}", adapter, prompt,
                            max_new_tokens=4))
    return reqs


# reports cached per mode: run.py's "prefill" entry and fig11's engine
# cross-check share one execution per sweep instead of repeating the trace
_reports: dict = {}


def _run(mode: str):
    if mode not in _reports:
        eng = _engine(mode)
        for r in _trace():
            eng.submit(r)
        _reports[mode] = eng.run(max_steps=100_000)
    return _reports[mode]


def run(out, prefix: str = "prefill") -> None:
    rep_b = _run("bucketed")
    rep_e = _run("eager")
    out.emit(f"{prefix}/bucketed/mean_ttft", rep_b.avg_ttft * 1e6,
             f"n={rep_b.n_finished};compiles={rep_b.prefill_compiles};"
             f"batch={rep_b.avg_prefill_batch:.2f};p99_q={rep_b.p99_queue:.3f}")
    out.emit(f"{prefix}/eager/mean_ttft", rep_e.avg_ttft * 1e6,
             f"n={rep_e.n_finished};p99_q={rep_e.p99_queue:.3f}")
    if rep_b.avg_ttft > 0:
        out.emit(f"{prefix}/summary/ttft_speedup",
                 rep_e.avg_ttft / rep_b.avg_ttft,
                 f"eager_over_bucketed;buckets<={rep_b.prefill_compiles}")
