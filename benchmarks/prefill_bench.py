"""Prefill/scheduling hot-path benchmark on a 32-request multi-LoRA trace.

Three-way comparison (real JAX execution on the reduced arch):

* ``mixed``     — Sarathi-style step scheduler (serving/scheduler.py): one
  row-masked ``extend`` per step packing decode tokens + budgeted prefill
  chunks (``schedule_mode="mixed"``);
* ``alternate`` — the PR-2 bucketed subsystem, one prefill call then one
  decode call per step (ablation pin);
* ``eager``     — the seed path: one exact-shape compile per distinct
  suffix length (correctness pin).

Mean TTFT over the trace is the paper's headline metric (Fig. 11); decode
TPOT p99 is the tail the mixed token budget must keep bounded. A discrete-
event simulator cross-check runs the same mode split at Llama-7B scale.

``run_recurrent`` adds the recurrent-reuse scenario: a repeated-prefix RWKV
trace where rounds after the first resume from state snapshots
(kvcache/state_cache.py) — snapshot-hit TTFT vs cold-prefix TTFT, paired
per prompt.

``run_shared_prefix`` adds the cross-adapter prefix-sharing scenario: N
adapters × one common system prompt, served with the shared-trunk cache
(``share_prefix_kv=True``) vs the per-adapter baseline — HBM KV hit-rate
gain plus paired-median TTFT ratio.

``run_mixed_slo`` adds the bursty mixed-SLO scenario: a batch-tier burst
that fills every slot plus interactive-tier arrivals mid-burst, served with
SLO-tiered admission + cost-model preemption vs the same trace with tier
metadata stripped (FCFS) — paired-median interactive TTFT ratio and batch
throughput ratio.

CLI: ``PYTHONPATH=src python benchmarks/prefill_bench.py
[--quick] [--recurrent] [--shared-prefix] [--mixed-slo]
[--trace-out PATH]``. ``--trace-out`` serves the trace once with
libra-trace armed and dumps Perfetto-loadable Chrome trace-event JSON
(given alone it skips the timed comparison — tracing a timed run would
perturb it).
"""

from __future__ import annotations

import numpy as np

from repro import configs
from repro.serving import EngineConfig, Request, ServingEngine

N_REQUESTS = 32
N_LORAS = 8

MODES = ("mixed", "alternate", "eager")


def _engine(mode: str, trace: bool = False):
    import dataclasses

    import jax

    cfg = configs.reduced(configs.get("qwen3-0.6b"))
    cfg = dataclasses.replace(
        cfg, lora=dataclasses.replace(cfg.lora, max_adapters=N_LORAS))
    ecfg = EngineConfig(
        hbm_bytes=16 << 20, host_bytes=64 << 20, block_size=4,
        max_batch_slots=8, max_seq_len=288,
        prefill_mode="eager" if mode == "eager" else "bucketed",
        prefill_chunk=64, prefill_min_bucket=8,
        schedule_mode="mixed" if mode == "mixed" else "alternate",
        # slots + slots × chunk: the budget admits full-ceiling chunks for
        # every row even with all slots decoding, so the comparison against
        # alternate mode isolates the scheduling structure
        step_token_budget=8 + 8 * 64, target_step_ms=0.0,
        trace=trace,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(0))
    for i in range(N_LORAS):
        eng.register_adapter(f"lora-{i}")
    return eng


def _trace(seed: int = 0, n: int = N_REQUESTS) -> list[Request]:
    """32 requests, zipf-distributed adapters, prompt lengths spanning every
    bucket (8..96 tokens) plus genuinely multi-chunk prompts (128–224 =
    2–4 chunks at the 64-token ceiling), 16-token decodes — the long-prompt
    multi-LoRA regime continuous chunked-prefill scheduling exists for."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        adapter = f"lora-{min(rng.zipf(1.5) - 1, N_LORAS - 1)}"
        plen = int(rng.choice([8, 11, 17, 23, 33, 47, 64, 96, 128, 160, 224]))
        prompt = tuple(int(t) for t in rng.randint(1, 900, size=plen))
        reqs.append(Request(f"pb{seed}-{i}", adapter, prompt,
                            max_new_tokens=16))
    return reqs


# reports cached per mode: run.py's "prefill" entry and fig11's engine
# cross-check share one execution per sweep instead of repeating the trace
_reports: dict = {}
_seed0_reports: dict = {}  # repeat-0 (seed-0 trace) reports, eager's trace
_pairs: dict = {}  # n -> [(mixed_rep, alternate_rep)] per repeat (same trace)
_process_warm = False


def _warm_process() -> None:
    """One discarded engine run before ANY timed mode: the first minute of
    JAX work in a fresh process (LLVM JIT, XLA thread pools, allocator
    arenas) runs several× slower and would be charged to whichever mode
    happens to go first, deciding the comparison by ordering."""
    global _process_warm
    if _process_warm:
        return
    _process_warm = True
    eng = _engine("alternate")
    for r in _trace(seed=7, n=N_REQUESTS):
        eng.submit(r)
    eng.run(max_steps=100_000)


REPEATS = 6  # ABBA-interleaved repeats for the mixed-vs-alternate comparison


def _warm_engine(mode: str):
    """Fresh engine with its hot shapes compiled: prompt lengths 96/17/11/8
    touch every bucket (64/32/16/8) plus the decode shape. Eager still pays
    per-length compiles for unseen lengths in the timed trace — that compile
    pathology is exactly what the bucketed modes amortize."""
    eng = _engine(mode)
    rng = np.random.RandomState(99)
    for i, plen in enumerate((96, 17, 11, 8)):
        prompt = tuple(int(t) for t in rng.randint(1, 900, size=plen))
        eng.submit(Request(f"warm-{i}", f"lora-{i % N_LORAS}", prompt,
                           max_new_tokens=4))
    eng.run(max_steps=100_000)
    eng.reset_metrics()
    return eng


def _mean_report(reports):
    """Average the latency/utilization fields across repeats (counts sum)."""
    import dataclasses as dc
    import statistics

    first = reports[0]
    if len(reports) == 1:
        return first
    mean = lambda f: statistics.fmean(getattr(r, f) for r in reports)
    return dc.replace(
        first,
        n_finished=sum(r.n_finished for r in reports),
        avg_ttft=mean("avg_ttft"), p99_ttft=mean("p99_ttft"),
        avg_tpot=mean("avg_tpot"), p99_tpot=mean("p99_tpot"),
        p99_queue=mean("p99_queue"), avg_step_ms=mean("avg_step_ms"),
        budget_utilization=mean("budget_utilization"),
        prefill_compiles=max(r.prefill_compiles for r in reports),
    )


def _run(mode: str, n: int = N_REQUESTS):
    """Timed trace(s) for one mode (cached). ``mixed`` and ``alternate``
    execute INTERLEAVED (m,a,m,a) so slow process warm-up / CPU drift —
    several× on this container — cancels instead of being charged to
    whichever mode runs first; their reports average the repeats."""
    key = (mode, n)
    if key in _reports:
        return _reports[key]
    _warm_process()
    if mode == "eager":
        eng = _warm_engine(mode)
        for r in _trace(n=n):
            eng.submit(r)
        _reports[key] = eng.run(max_steps=100_000)
        return _reports[key]
    engines = {m: _warm_engine(m) for m in ("mixed", "alternate")}
    collected = {m: [] for m in engines}
    for rep in range(-1, REPEATS):
        # ABBA counterbalancing: the process keeps speeding up for a while,
        # so a fixed (m, a) order would hand the later position — and the
        # faster clock — to the same mode every repeat. rep -1 is an
        # unrecorded burn-in pair: the first measured window in a fresh
        # process is reliably the slowest and always lands on one mode.
        order = ("mixed", "alternate") if rep % 2 == 0 else ("alternate", "mixed")
        for m in order:
            eng = engines[m]
            # burn-in uses its own seed so measured traces stay prefix-cold
            for r in _trace(seed=rep if rep >= 0 else 1000, n=n):
                eng.submit(r)
            rep_report = eng.run(max_steps=100_000)
            eng.reset_metrics()
            if rep >= 0:
                collected[m].append(rep_report)
    for m, reps in collected.items():
        _reports[(m, n)] = _mean_report(reps)
        _seed0_reports[(m, n)] = reps[0]
    _pairs[n] = list(zip(collected["mixed"], collected["alternate"]))
    return _reports[key]


def _paired_ratio(pairs, field) -> float:
    """Median of per-repeat alternate/mixed ratios.

    Each repeat serves the SAME trace in both modes back-to-back, so the
    paired ratio cancels the slow CPU-clock drift that an aggregate-mean
    comparison across disjoint time windows soaks up as noise; the median
    (not mean) discards the occasional window a GC pause or stray compile
    lands in, which otherwise swings single pairs by ±15%."""
    import statistics

    ratios = [getattr(a, field) / getattr(m, field)
              for m, a in pairs
              if getattr(m, field) > 0 and getattr(a, field) > 0]
    return statistics.median(ratios) if ratios else 0.0


def _emit_mode(out, prefix: str, mode: str, rep) -> None:
    try:
        from benchmarks.common import emit_report
    except ImportError:  # invoked as a script from benchmarks/
        from common import emit_report

    emit_report(out, f"{prefix}/{mode}/mean_ttft", rep.avg_ttft * 1e6, rep,
                ("n=n_finished", "compiles=prefill_compiles",
                 "batch=avg_prefill_batch:.2f", "p99_q=p99_queue:.3f",
                 "stall=avg_stall:.4f"))
    emit_report(out, f"{prefix}/{mode}/p99_tpot", rep.p99_tpot * 1e6, rep,
                ("step_ms=avg_step_ms:.2f",
                 "budget_util=budget_utilization:.3f"))


def run(out, prefix: str = "prefill", n: int = N_REQUESTS) -> None:
    reps = {mode: _run(mode, n) for mode in MODES}
    for mode in MODES:
        _emit_mode(out, prefix, mode, reps[mode])
    rep_m, rep_a, rep_e = reps["mixed"], reps["alternate"], reps["eager"]
    # eager runs the seed-0 trace once; compare it against mixed's seed-0
    # repeat so the ratio is over an identical workload
    rep_m0 = _seed0_reports.get(("mixed", n), rep_m)
    if rep_m0.avg_ttft > 0:
        out.emit(f"{prefix}/summary/ttft_speedup_vs_eager",
                 rep_e.avg_ttft / rep_m0.avg_ttft,
                 f"eager_over_mixed;seed0;buckets<={rep_m.prefill_compiles}")
    pairs = _pairs.get(n, [])
    if pairs:
        out.emit(f"{prefix}/summary/ttft_speedup_vs_alternate",
                 _paired_ratio(pairs, "avg_ttft"),
                 f"alternate_over_mixed;paired_median;reps={len(pairs)}")
        ratio = _paired_ratio(pairs, "p99_tpot")
        out.emit(f"{prefix}/summary/tpot_p99_ratio",
                 1.0 / ratio if ratio else 0.0,
                 "mixed_over_alternate;paired_median;target<=1.25")


def run_recurrent(out, prefix: str = "prefill/recurrent",
                  n_prompts: int = 6, rounds: int = 3,
                  plen: int = 96) -> None:
    """Recurrent-reuse scenario: a repeated-prefix RWKV trace.

    Round 0 serves ``n_prompts`` distinct multi-LoRA prompts cold (each
    commit captures a state snapshot at its ``len(prompt)-1`` boundary);
    rounds 1.. repeat the same prompts, which must resume from the snapshots
    and prefill a single token. Reported: cold vs snapshot-hit mean TTFT and
    the per-prompt paired-median hit/cold ratio (the pairing cancels CPU
    drift; target < 1.0), plus the engine's state_hit_rate."""
    import dataclasses
    import statistics

    import jax

    cfg = configs.reduced(configs.get("rwkv6-1.6b"))
    cfg = dataclasses.replace(
        cfg, lora=dataclasses.replace(cfg.lora, max_adapters=N_LORAS))
    ecfg = EngineConfig(
        hbm_bytes=24 << 20, host_bytes=96 << 20, block_size=4,
        max_batch_slots=8, max_seq_len=160,
        prefill_mode="bucketed", prefill_chunk=64, prefill_min_bucket=8,
        schedule_mode="mixed", step_token_budget=8 + 8 * 64,
    )
    eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(0))
    for i in range(N_LORAS):
        eng.register_adapter(f"lora-{i}")
    # burn-in: hot shapes + process warm-up on throwaway prompts. Two passes
    # over the SAME prompts so both the cold path (capture/flatten) and the
    # resume path (snapshot seed) have their one-time jit compiles behind
    # them before any timed round.
    rng = np.random.RandomState(11)
    warm = [tuple(int(t) for t in rng.randint(1, 900, size=plen))
            for _ in range(4)]
    for rnd in range(2):
        for i, p in enumerate(warm):
            eng.submit(Request(f"rwarm{rnd}-{i}", f"lora-{i}", p,
                               max_new_tokens=4))
        eng.run(max_steps=100_000)
    eng.reset_metrics()

    rng = np.random.RandomState(5)
    prompts = [tuple(int(t) for t in rng.randint(1, 900, size=plen))
               for _ in range(n_prompts)]
    ttfts: list[list[float]] = [[] for _ in prompts]
    for rnd in range(rounds):
        reqs = [Request(f"rec{rnd}-{i}", f"lora-{i % N_LORAS}", p,
                        max_new_tokens=8) for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        rep = eng.run(max_steps=100_000)
        for i, r in enumerate(reqs):
            assert r.ttft is not None
            if rnd > 0:
                assert r.matched_tokens == len(r.prompt) - 1, (
                    "repeat round missed the snapshot cache")
            ttfts[i].append(r.ttft)
    cold = [t[0] for t in ttfts]
    hit = [statistics.median(t[1:]) for t in ttfts]
    ratios = [h / c for h, c in zip(hit, cold) if c > 0]
    ratio = statistics.median(ratios) if ratios else 0.0
    hit_rate = eng.manager.stats.state_hit_rate()
    out.emit(f"{prefix}/cold/mean_ttft", statistics.fmean(cold) * 1e6,
             f"n={len(cold)};plen={plen}")
    out.emit(f"{prefix}/hit/mean_ttft", statistics.fmean(hit) * 1e6,
             f"n={len(hit)};rounds={rounds - 1};state_hit_rate={hit_rate:.3f}")
    out.emit(f"{prefix}/summary/hit_over_cold_ttft", ratio,
             f"paired_median;target<1.0;state_hit_rate={hit_rate:.3f}")


def run_shared_prefix(out, prefix: str = "prefill/shared",
                      repeats: int = 4, slen: int = 24, tail: int = 40) -> None:
    """Cross-adapter prefix-sharing scenario: N adapters × ONE system prompt.

    Each repeat generates a fresh shared system prompt plus per-adapter
    tails, then serves one request per adapter sequentially on TWO engines —
    ``share_prefix_kv=True`` (trunk caching) and ``False`` (per-adapter
    baseline). Both compute the span with the adapter inactive, so the only
    difference is the caching layer: with sharing, adapters 1..N-1 hit trunk
    KV that adapter 0 computed; without it every adapter prefills the span
    cold. Reported: HBM KV hit rates, shared-span hit rate, warm-position
    mean TTFT per config, and the per-repeat paired-median shared/unshared
    TTFT ratio (pairing cancels CPU-clock drift; target <= 1.0)."""
    import dataclasses
    import statistics

    import jax

    def build(share: bool) -> ServingEngine:
        cfg = configs.reduced(configs.get("qwen3-0.6b"))
        cfg = dataclasses.replace(
            cfg, lora=dataclasses.replace(cfg.lora, max_adapters=N_LORAS))
        ecfg = EngineConfig(
            hbm_bytes=16 << 20, host_bytes=64 << 20, block_size=4,
            max_batch_slots=8, max_seq_len=288,
            prefill_mode="bucketed", prefill_chunk=64, prefill_min_bucket=8,
            schedule_mode="mixed", step_token_budget=8 + 8 * 64,
            share_prefix_kv=share,
        )
        eng = ServingEngine(cfg, ecfg, key=jax.random.PRNGKey(0))
        for i in range(N_LORAS):
            eng.register_adapter(f"lora-{i}")
        return eng

    engines = {True: build(True), False: build(False)}
    # burn-in: one throwaway repeat per engine compiles both the base-row
    # span path and the adapter path before anything is timed
    rng = np.random.RandomState(23)
    for share, eng in engines.items():
        sys_p = tuple(int(t) for t in rng.randint(1, 900, size=slen))
        for i in range(N_LORAS):
            t = tuple(int(x) for x in rng.randint(1, 900, size=tail))
            eng.submit(Request(f"spwarm{share}-{i}", f"lora-{i}", sys_p + t,
                               max_new_tokens=4, shared_prefix_len=slen))
            eng.run(max_steps=100_000)
        eng.reset_metrics()

    rng = np.random.RandomState(3)
    warm_ttfts: dict[bool, list[float]] = {True: [], False: []}
    ratios: list[float] = []
    for rep in range(repeats):
        sys_p = tuple(int(t) for t in rng.randint(1, 900, size=slen))
        tails = [tuple(int(x) for x in rng.randint(1, 900, size=tail))
                 for _ in range(N_LORAS)]
        # ABBA counterbalancing across repeats: CPU drift cancels in pairs
        order = (True, False) if rep % 2 == 0 else (False, True)
        rep_mean: dict[bool, float] = {}
        for share in order:
            eng = engines[share]
            ttfts = []
            for i, t in enumerate(tails):
                r = Request(f"sp{rep}-{share}-{i}", f"lora-{i}", sys_p + t,
                            max_new_tokens=8, shared_prefix_len=slen)
                eng.submit(r)
                eng.run(max_steps=100_000)
                assert r.ttft is not None
                ttfts.append(r.ttft)
            # warm positions only: adapter 0 seeds the trunk (cold in both
            # configs); 1..N-1 are where sharing can pay
            warm_ttfts[share].extend(ttfts[1:])
            rep_mean[share] = statistics.fmean(ttfts[1:])
        if rep_mean[False] > 0:
            ratios.append(rep_mean[True] / rep_mean[False])
    shared_stats = engines[True].manager.stats
    unshared_stats = engines[False].manager.stats
    hit_gain = shared_stats.kv_hit_rate() - unshared_stats.kv_hit_rate()
    ratio = statistics.median(ratios) if ratios else 0.0
    out.emit(f"{prefix}/shared/mean_ttft",
             statistics.fmean(warm_ttfts[True]) * 1e6,
             f"n={len(warm_ttfts[True])};adapters={N_LORAS};"
             f"kv_hit={shared_stats.kv_hit_rate():.3f};"
             f"shared_hit={shared_stats.shared_hit_rate():.3f}")
    out.emit(f"{prefix}/unshared/mean_ttft",
             statistics.fmean(warm_ttfts[False]) * 1e6,
             f"n={len(warm_ttfts[False])};adapters={N_LORAS};"
             f"kv_hit={unshared_stats.kv_hit_rate():.3f}")
    out.emit(f"{prefix}/summary/shared_over_unshared_ttft", ratio,
             f"paired_median;target<=1.0;reps={len(ratios)}")
    out.emit(f"{prefix}/summary/kv_hit_rate_gain", hit_gain,
             "shared_minus_unshared;target>0")


def run_mixed_slo(out, prefix: str = "prefill/slo", repeats: int = 4,
                  n_batch: int = 20, n_inter: int = 4,
                  plen: int = 96, ilen: int = 24) -> None:
    """Bursty mixed-SLO scenario: SLO-tiered admission + preemption vs FCFS.

    Each repeat floods the engine with a burst of batch-tier requests (long
    prompts, 32-token decodes, ~2.5x the slot count so a deep backlog
    queues behind the running wave), steps until the burst owns the
    machine, then injects short interactive-tier requests mid-burst. The tiered engine ranks them ahead in the cost-ranked queue,
    preempts batch victims — whose computed KV demotes through the two-tier
    pool and resumes token-identically — and fast-lanes their prefill; the
    FCFS baseline serves the IDENTICAL trace with the tier metadata
    stripped, so interactive requests wait behind the burst. Reported:
    interactive mean TTFT per config, the per-repeat paired-median
    tiered/FCFS interactive TTFT ratio (target < 1.0), and the batch
    makespan-derived throughput ratio (target >= 0.9: preemption must not
    melt batch throughput)."""
    import statistics

    from repro.serving.request import PRIORITY_INTERACTIVE

    engines = {True: _engine("mixed"), False: _engine("mixed")}
    # burn-in: one throwaway burst per engine compiles every shape on both
    # the plain path and (tiered) the preempt/resume path before timing
    # the warm burst matches the timed burst EXACTLY (same n_batch, same
    # lengths): a smaller warm burst stays under pool pressure and leaves
    # the first demotion/transfer shapes to compile inside the timed region
    rng = np.random.RandomState(41)
    for tiered, eng in engines.items():
        for i in range(n_batch):
            p = tuple(int(t) for t in rng.randint(1, 900, size=plen))
            eng.submit(Request(f"slowarm{tiered}-b{i}",
                               f"lora-{i % N_LORAS}", p, max_new_tokens=32))
        for _ in range(3):
            eng.step()
        for i in range(n_inter):
            p = tuple(int(t) for t in rng.randint(1, 900, size=ilen))
            kw = (dict(priority=PRIORITY_INTERACTIVE,
                       deadline=eng.now() + 0.05) if tiered else {})
            eng.submit(Request(f"slowarm{tiered}-i{i}",
                               f"lora-{i % N_LORAS}", p,
                               max_new_tokens=8, **kw))
        eng.run(max_steps=100_000)
        eng.reset_metrics()

    rng = np.random.RandomState(17)
    inter_ttfts: dict[bool, list[float]] = {True: [], False: []}
    ttft_ratios: list[float] = []
    tput_ratios: list[float] = []
    preemptions = 0
    for rep in range(repeats):
        # one workload per repeat, served by BOTH configs (paired); fresh
        # token ranges per repeat keep every prefix cold across repeats
        bprompts = [tuple(int(t) for t in rng.randint(1, 900, size=plen))
                    for _ in range(n_batch)]
        iprompts = [tuple(int(t) for t in rng.randint(1, 900, size=ilen))
                    for _ in range(n_inter)]
        # ABBA counterbalancing across repeats: CPU drift cancels in pairs
        order = (True, False) if rep % 2 == 0 else (False, True)
        rep_ttft: dict[bool, float] = {}
        rep_makespan: dict[bool, float] = {}
        for tiered in order:
            eng = engines[tiered]
            batch = [Request(f"slo{rep}-{tiered}-b{i}",
                             f"lora-{i % N_LORAS}", p, max_new_tokens=32)
                     for i, p in enumerate(bprompts)]
            for r in batch:
                eng.submit(r)
            # let the burst occupy the machine before the interactive
            # arrivals land mid-flight
            for _ in range(3):
                eng.step()
            inter = []
            for i, p in enumerate(iprompts):
                kw = (dict(priority=PRIORITY_INTERACTIVE,
                           deadline=eng.now() + 0.05) if tiered else {})
                r = Request(f"slo{rep}-{tiered}-i{i}",
                            f"lora-{(n_batch + i) % N_LORAS}", p,
                            max_new_tokens=8, **kw)
                inter.append(r)
                eng.submit(r)
            eng.run(max_steps=100_000)
            assert all(r.ttft is not None for r in inter + batch)
            rep_ttft[tiered] = statistics.fmean(r.ttft for r in inter)
            rep_makespan[tiered] = (max(r.finish_time for r in batch)
                                    - min(r.submit_time for r in batch))
            inter_ttfts[tiered].append(rep_ttft[tiered])
            if tiered:
                preemptions += eng.manager.stats.preemptions
            eng.reset_metrics()
        if rep_ttft[False] > 0:
            ttft_ratios.append(rep_ttft[True] / rep_ttft[False])
        if rep_makespan[True] > 0:
            # same token workload both ways: throughput ratio is the
            # inverted makespan ratio
            tput_ratios.append(rep_makespan[False] / rep_makespan[True])
    ttft_ratio = statistics.median(ttft_ratios) if ttft_ratios else 0.0
    tput_ratio = statistics.median(tput_ratios) if tput_ratios else 0.0
    out.emit(f"{prefix}/tiered/interactive_mean_ttft",
             statistics.fmean(inter_ttfts[True]) * 1e6,
             f"n={repeats * n_inter};preemptions={preemptions}")
    out.emit(f"{prefix}/fcfs/interactive_mean_ttft",
             statistics.fmean(inter_ttfts[False]) * 1e6,
             f"n={repeats * n_inter}")
    out.emit(f"{prefix}/summary/tiered_over_fcfs_interactive_ttft",
             ttft_ratio,
             f"paired_median;target<1.0;reps={len(ttft_ratios)}")
    out.emit(f"{prefix}/summary/batch_throughput_ratio", tput_ratio,
             f"tiered_over_fcfs;paired_median;target>=0.9;"
             f"preemptions={preemptions}")


def trace_run(path: str, n: int = N_REQUESTS) -> None:
    """One traced mixed-mode pass over the seed-0 multi-LoRA trace: arms
    libra-trace on a fresh engine, serves the trace, and dumps Chrome
    trace-event JSON to ``path`` (load it in Perfetto or summarize with
    ``python -m repro.obs.report``). Untimed — tracing is for inspection,
    the timed comparisons above always run with the tracer disabled."""
    eng = _engine("mixed", trace=True)
    for r in _trace(n=n):
        eng.submit(r)
    eng.run(max_steps=100_000)
    eng.export_trace(path)
    print(f"# wrote trace to {path} "
          f"(summarize: python -m repro.obs.report {path})")


def run_sim_modes(out, prefix: str = "prefill/sim") -> None:
    """Simulator cross-check: the same mode split at Llama-7B scale."""
    try:
        from benchmarks.common import run_sim
    except ImportError:  # invoked as a script from benchmarks/
        from common import run_sim

    for mode in ("mixed", "alternate"):
        res = run_sim("llama-7b", "chatbot", "fastlibra", n_loras=100,
                      qps=4.0, duration=120.0, schedule_mode=mode,
                      step_token_budget=256)
        tpots = sorted(r.tpot for r in res.finished if r.tpot is not None)
        p99 = tpots[min(len(tpots) - 1, int(0.99 * len(tpots)))] if tpots else 0.0
        out.emit(f"{prefix}/{mode}/mean_ttft", res.avg_ttft * 1e6,
                 f"n={len(res.finished)}")
        out.emit(f"{prefix}/{mode}/p99_tpot", p99 * 1e6, "")


def main() -> None:
    import argparse

    try:
        from benchmarks.common import CsvOut
    except ImportError:  # invoked as a script from benchmarks/
        from common import CsvOut

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="12-request trace, engine comparison only")
    ap.add_argument("--no-sim", action="store_true",
                    help="skip the simulator cross-check")
    ap.add_argument("--recurrent", action="store_true",
                    help="run ONLY the recurrent snapshot-reuse scenario")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run ONLY the cross-adapter prefix-sharing scenario")
    ap.add_argument("--mixed-slo", action="store_true",
                    help="run ONLY the bursty mixed-SLO tiering scenario")
    ap.add_argument("--trace-out", default="", metavar="PATH",
                    help="serve the 32-request trace once with libra-trace "
                         "armed and dump Chrome trace-event JSON here "
                         "(Perfetto-loadable; see README §Observability)")
    args = ap.parse_args()
    out = CsvOut()
    if args.trace_out:
        trace_run(args.trace_out, n=12 if args.quick else N_REQUESTS)
        if not (args.recurrent or args.shared_prefix or args.mixed_slo):
            return
    if args.recurrent:
        run_recurrent(out, n_prompts=4 if args.quick else 6,
                      rounds=3, plen=64 if args.quick else 96)
        return
    if args.shared_prefix:
        run_shared_prefix(out, repeats=2 if args.quick else 4,
                          slen=16 if args.quick else 24,
                          tail=24 if args.quick else 40)
        return
    if args.mixed_slo:
        run_mixed_slo(out, repeats=2 if args.quick else 4,
                      n_batch=14 if args.quick else 20,
                      n_inter=3 if args.quick else 4,
                      plen=64 if args.quick else 96,
                      ilen=16 if args.quick else 24)
        return
    run(out, n=12 if args.quick else N_REQUESTS)
    if not args.quick:
        run_recurrent(out)
        run_shared_prefix(out)
        run_mixed_slo(out)
    if not (args.quick or args.no_sim):
        run_sim_modes(out)


if __name__ == "__main__":
    main()
