"""Fig. 5 — LRU time vs visit frequency vs swap cost are uncorrelated.

Runs a chatbot trace, then rank-correlates the three factors over all cache
nodes: low |Spearman ρ| justifies the multi-factor cost model over LRU.
"""

from .common import CsvOut, run_sim


def _spearman(a: list[float], b: list[float]) -> float:
    n = len(a)
    if n < 3:
        return 0.0

    def ranks(v):
        order = sorted(range(n), key=lambda i: v[i])
        r = [0.0] * n
        for rank, i in enumerate(order):
            r[i] = rank
        return r

    ra, rb = ranks(a), ranks(b)
    ma = sum(ra) / n
    mb = sum(rb) / n
    cov = sum((x - ma) * (y - mb) for x, y in zip(ra, rb))
    va = sum((x - ma) ** 2 for x in ra) ** 0.5
    vb = sum((y - mb) ** 2 for y in rb) ** 0.5
    return cov / (va * vb + 1e-12)


def run(out: CsvOut) -> None:
    res = run_sim("llama-7b", "chatbot", "fastlibra", n_loras=50)
    nodes = [n for n in res.manager.tree.iter_nodes() if n.size_bytes > 0]
    now = res.duration
    lru = [now - n.last_access for n in nodes]
    freq = [n.decayed_visits(now, res.manager.tree.decay_tau) for n in nodes]
    cost = [float(n.size_bytes) for n in nodes]
    r1 = _spearman(lru, freq)
    r2 = _spearman(lru, cost)
    r3 = _spearman(freq, cost)
    out.emit(
        "fig5/correlations",
        float(len(nodes)),
        f"spearman_lru_freq={r1:.3f};lru_cost={r2:.3f};freq_cost={r3:.3f}",
    )
