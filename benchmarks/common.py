"""Shared benchmark harness: simulator runs, CSV emission, timing."""

from __future__ import annotations

import os
import time

from repro import configs
from repro.data import TraceConfig, generate_trace
from repro.sim import DeployedModel, ServingSimulator, SimConfig

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"

# paper deployment mapping (Table 1 / §6.1)
CARDS = {"llama-7b": 1, "llama-13b": 2, "llama-34b": 4}

# default operating points (sending rate, qps) per scenario, chosen inside
# each system's serviceable region so TTFT reflects caching, not saturation
RATES = {"chatbot": 1.2, "translation": 6.0, "agent": 1.0}

# paper §6.3 methodology: sweep sending rates from 0 to peak and average
SWEEP = (0.5, 0.75, 1.0, 1.25)

DURATION = 180.0 if QUICK else 420.0


def deployed(model: str) -> DeployedModel:
    return DeployedModel(configs.get(model), cards=CARDS.get(model, 1))


_trace_cache: dict = {}


def trace(scenario: str, n_loras: int, qps: float | None = None,
          duration: float | None = None, seed: int = 0, dist: str = "zipf"):
    key = (scenario, n_loras, qps, duration, seed, dist)
    if key not in _trace_cache:
        _trace_cache[key] = generate_trace(TraceConfig(
            scenario=scenario,
            n_loras=n_loras,
            duration=duration or DURATION,
            mean_qps=qps or RATES[scenario],
            seed=seed,
            distribution=dist,
        ))
    return _trace_cache[key]


def run_sim(model: str, scenario: str, variant: str, n_loras: int = 50,
            qps: float | None = None, seed: int = 0, dist: str = "zipf",
            duration: float | None = None, **simkw):
    tr = trace(scenario, n_loras, qps, duration, seed, dist)
    sim = ServingSimulator(
        deployed(model), tr, SimConfig(variant=variant, **simkw), seed=seed
    )
    t0 = time.perf_counter()
    res = sim.run()
    res.wall_seconds = time.perf_counter() - t0
    return res


def run_sweep(model: str, scenario: str, variant: str, n_loras: int = 50,
              seed: int = 0):
    """Paper §6.3: run a sweep of sending rates up to ~peak and average
    TTFT/TPOT across them. Returns (avg_ttft, avg_tpot, results)."""
    base = RATES[scenario]
    sweep = SWEEP[:2] if QUICK else SWEEP
    results = [
        run_sim(model, scenario, variant, n_loras=n_loras,
                qps=base * m, seed=seed,
                duration=120.0 if QUICK else 240.0)
        for m in sweep
    ]
    ttft = sum(r.avg_ttft for r in results) / len(results)
    tpot = sum(r.avg_tpot for r in results) / len(results)
    return ttft, tpot, results


class CsvOut:
    """Collects ``name,us_per_call,derived`` rows (harness convention)."""

    def __init__(self):
        self.rows: list[tuple[str, float, str]] = []

    def emit(self, name: str, us_per_call: float, derived: str = "") -> None:
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}")

    def write_csv(self, path: str) -> None:
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for name, us, derived in self.rows:
                f.write(f"{name},{us:.3f},{derived}\n")


def fmt_fields(row, fields=(), **extra) -> str:
    """Build the harness's ``k=v;k2=v2`` derived string from a mapping.

    Each entry of ``fields`` is ``"alias=key:fmt"`` — ``alias=`` and
    ``:fmt`` both optional, so ``"n=n_finished"``, ``"p99_q=p99_queue:.3f"``
    and ``"dominant"`` all work. ``extra`` appends pre-formatted literals.
    This is THE derived-string builder: every fig/prefill/kernels bench row
    routes through it (via :func:`emit_report` for report-backed rows), so
    field renames surface as KeyErrors here instead of silently drifting
    f-strings apart across benchmark modules.
    """
    parts = []
    for spec in fields:
        alias, sep, rhs = spec.partition("=")
        key = rhs if sep else alias
        key, fsep, fmt = key.partition(":")
        if not sep:
            alias = key
        v = row[key]
        parts.append(f"{alias}={format(v, fmt) if fsep else v}")
    for k, v in extra.items():
        parts.append(f"{k}={v}")
    return ";".join(parts)


def emit_report(out: CsvOut, name: str, us_per_call: float, report,
                fields=(), **extra) -> None:
    """Emit one CSV row whose derived string is drawn from a report.

    ``report`` is anything with a ``.row()`` (``ServingReport``) or a plain
    mapping (e.g. ``SimResult.summary()``); ``fields``/``extra`` follow
    :func:`fmt_fields`. New ``ServingReport`` fields become available to
    every benchmark's derived strings without touching the emitters.
    """
    row = report.row() if hasattr(report, "row") else report
    out.emit(name, us_per_call, fmt_fields(row, fields, **extra))


def peak_throughput(model: str, scenario: str, variant: str, n_loras: int,
                    ttft_slo: float = 0.5, rates=None) -> float:
    """Paper metric: max sending rate with avg TTFT below the 500 ms SLO."""
    rates = rates or ([0.5, 1.0, 2.0] if QUICK else [0.5, 1.0, 1.5, 2.0, 3.0, 4.0])
    best = 0.0
    for r in rates:
        res = run_sim(model, scenario, variant, n_loras=n_loras, qps=r,
                      duration=120.0 if QUICK else 240.0)
        if res.avg_ttft <= ttft_slo:
            best = max(best, len(res.finished) / res.duration)
        else:
            break
    return best
