"""Fig. 9 — vLLM TTFT vs the static HBM ratio allocated to LoRAs.

Sweeps the partition ratio at 50 and 100 adapters: TTFT falls until a
load-dependent target ratio, showing no single static split is right.
"""

from repro.core.cache_manager import ManagerConfig
import repro.core.swapper as swmod

from .common import CsvOut, QUICK, run_sim


def run(out: CsvOut) -> None:
    ratios = (0.1, 0.3) if QUICK else (0.05, 0.1, 0.2, 0.3, 0.4)
    orig = swmod.make_fastlibra
    for n_loras in (50, 100):
        for ratio in ratios:
            def patched(hbm, host, *, kv_bytes_per_token, block_size=32,
                        hardware=None, variant="vllm", _r=ratio):
                from repro.core.cache_manager import CacheManager
                from repro.core.swapper import CacheSwapper, SwapperConfig

                cfg = ManagerConfig(
                    block_size=block_size,
                    kv_bytes_per_token=kv_bytes_per_token,
                    maintain_dependencies=False,
                    unified_pool=False,
                    use_cost_model=False,
                    lora_partition_ratio=_r,
                )
                mgr = CacheManager(cfg, hbm, host, hardware=hardware)
                return mgr, CacheSwapper(mgr, SwapperConfig(enabled=False))

            swmod.make_fastlibra = patched
            import repro.sim.simulator as simmod

            simmod.make_fastlibra = patched
            try:
                res = run_sim("llama-7b", "chatbot", "vllm", n_loras=n_loras)
            finally:
                swmod.make_fastlibra = orig
                simmod.make_fastlibra = orig
            out.emit(
                f"fig9/ratio_{ratio}/loras_{n_loras}",
                res.avg_ttft * 1e6,
                f"lora_hit={res.summary()['lora_hit_rate']:.3f}",
            )
