"""Fig. 2 — vLLM TTFT over time for the three scenarios (llama-7b).

Reproduces the motivation: static-partition vLLM shows TTFT spikes when the
KV or LoRA region exhausts under load shifts.
"""

from .common import CsvOut, run_sim


def run(out: CsvOut) -> None:
    for scenario in ("chatbot", "translation", "agent"):
        res = run_sim("llama-7b", scenario, "vllm", n_loras=50)
        spikes = max((t["window_ttft"] for t in res.timeline), default=0.0)
        out.emit(
            f"fig2/{scenario}/vllm_avg_ttft_ms",
            res.avg_ttft * 1e6,
            f"max_window_ttft_ms={spikes*1e3:.1f};n={len(res.finished)}",
        )
