"""Fig. 13 — average HBM utilization and LoRA/KV cache hit rates, plus the
beyond-paper recurrent series: state-snapshot hit rates when the prefix
layer is RWKV state snapshots instead of per-token KV."""

import statistics

from .common import CsvOut, run_sim


def run(out: CsvOut) -> None:
    agg = {}
    for scenario in ("chatbot", "translation", "agent"):
        for sysname in ("fastlibra", "vllm", "slora"):
            res = run_sim("llama-7b", scenario, sysname, n_loras=50)
            s = res.summary()
            agg.setdefault(sysname, []).append(s)
            out.emit(
                f"fig13/{scenario}/{sysname}",
                s["avg_hbm_usage"] * 1e6,
                f"kv_hit={s['kv_hit_rate']:.3f};lora_hit={s['lora_hit_rate']:.3f};"
                f"invalid_kv={s['avg_invalid_kv']:.3f}",
            )
        # recurrent-state reuse series: same trace shape, snapshot nodes
        res = run_sim("rwkv6-1.6b", scenario, "fastlibra", n_loras=50)
        s = res.summary()
        out.emit(
            f"fig13/{scenario}/fastlibra-rwkv6",
            s["avg_hbm_usage"] * 1e6,
            f"state_hit={s['state_hit_rate']:.3f};"
            f"lora_hit={s['lora_hit_rate']:.3f}",
        )
    fl = agg["fastlibra"]
    for base in ("vllm", "slora"):
        b = agg[base]
        util_x = statistics.fmean(x["avg_hbm_usage"] for x in fl) / max(
            1e-9, statistics.fmean(x["avg_hbm_usage"] for x in b)
        )
        hit_fl = statistics.fmean(
            x["kv_hit_rate"] + x["lora_hit_rate"] for x in fl
        )
        hit_b = statistics.fmean(
            x["kv_hit_rate"] + x["lora_hit_rate"] for x in b
        )
        out.emit(
            f"fig13/summary/vs_{base}",
            util_x,
            f"hbm_util_x={util_x:.2f} (paper 1.2x vllm / 2.6x slora); "
            f"hit_x={hit_fl/max(1e-9,hit_b):.2f} (paper 1.3x / 3.2x)",
        )
