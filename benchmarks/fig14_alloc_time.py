"""Fig. 14 — HBM allocation over time (history KV / LoRA / running KV)."""

from .common import CsvOut, run_sim


def run(out: CsvOut) -> None:
    for sysname in ("fastlibra", "vllm", "slora"):
        res = run_sim("llama-7b", "chatbot", sysname, n_loras=100)
        # report quartile snapshots of the timeline
        tl = res.timeline
        for frac in (0.1, 0.4, 0.7, 1.0):
            i = min(len(tl) - 1, int(frac * len(tl)) - 1)
            t = tl[i]
            tot = max(1, t["total_bytes"])
            out.emit(
                f"fig14/{sysname}/t{int(frac*100)}",
                t["t"] * 1e6,
                f"hist_kv={t['history_kv_bytes']/tot:.3f};"
                f"lora={t['lora_bytes']/tot:.3f};"
                f"running={t['running_kv_bytes']/tot:.3f};"
                f"resident_loras={t['resident_loras']}",
            )
